"""Multi-tenant SLO-aware serving (`serving/tenancy.py` + `rollout.py` +
the scheduler's tenant wiring): token-bucket quotas, weighted deficit
round-robin fair share, the graceful-degradation ladder, and zero-loss
versioned plan hot-swap.  Everything is deterministic: servers run on an
injected clock and are driven by synchronous :meth:`step` ticks; the
property-based fairness test runs through hypothesis when installed and
the deterministic `_hypothesis_fallback` sweep otherwise."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.core.graph import compile_plan, optimize
from repro.models.cnn import APPS, app_masks
from repro.obs import metrics
from repro.serving import (
    AsyncPlanServer,
    DeficitRoundRobin,
    LadderConfig,
    LadderShedError,
    QuotaExceededError,
    SwapError,
    Tenant,
    TenantSLO,
    TokenBucket,
    submit_with_retry,
)

KEY = jax.random.PRNGKey(0)
FRAME = (3, 8, 8)  # super_resolution single-frame shape at base=8


def _plan(app="super_resolution"):
    g = APPS[app](KEY, base=8)
    masks, structures = app_masks(g, app, sparsity=0.5)
    go = optimize(g, masks, structures)
    return go, compile_plan(go, backend="reference")


@pytest.fixture(scope="module")
def sr():
    return _plan()


def _server(sr, clock=None, **kw):
    go, plan = sr
    server = AsyncPlanServer(clock=clock or (lambda: 0.0), **kw)
    server.add_plan("sr", plan, go.params, batch_size=4)
    return server


def _frames(n, shape=FRAME):
    return [jax.random.normal(jax.random.PRNGKey(i), shape) for i in range(n)]


def _scale_params(params, factor):
    """Scale only the float leaves (sparse formats carry integer indices)."""
    return jax.tree_util.tree_map(
        lambda a: a * factor
        if jnp.issubdtype(jnp.asarray(a).dtype, jnp.floating) else a,
        params,
    )


# --------------------------------------------------------------------------- #
# units: token bucket, deficit round-robin, ladder hysteresis                  #
# --------------------------------------------------------------------------- #


def test_token_bucket_refills_at_rate_and_caps_at_burst():
    b = TokenBucket(rate=10.0, burst=3.0)
    assert [b.take(0.0) for _ in range(3)] == [True, True, True]
    assert not b.take(0.0)  # burst exhausted
    assert not b.take(0.05)  # 0.5 tokens accrued: still < 1
    assert b.take(0.1)  # 1 token accrued
    # a long idle period caps at burst, it does not bank unbounded credit
    assert [b.take(100.0) for _ in range(3)] == [True, True, True]
    assert not b.take(100.0)


def test_token_bucket_unlimited_and_validation():
    b = TokenBucket(None)
    assert all(b.take(t) for t in (0.0, 0.0, 1e9))
    with pytest.raises(ValueError, match="rate"):
        TokenBucket(0.0)
    assert TokenBucket(1.0, burst=0.01).burst == 1.0  # floored: must admit


def test_drr_long_run_share_is_weight_proportional():
    drr = DeficitRoundRobin()
    taken = {"a": 0, "b": 0}
    for _ in range(16):  # 16 batches of 4 slots, both queues backlogged
        cands = {"a": list(range(8)), "b": list(range(8))}
        got = drr.select(cands, {"a": 3.0, "b": 1.0}, 4)
        assert len(got) == 4
        taken["a"] += 8 - len(cands["a"])
        taken["b"] += 8 - len(cands["b"])
    # weight 3:1 over 64 slots -> 48/16 exactly (whole-unit deficits)
    assert taken == {"a": 48, "b": 16}


def test_drr_small_weight_never_starves():
    drr = DeficitRoundRobin()
    got_b = 0
    for _ in range(40):
        cands = {"a": list(range(8)), "b": list(range(8))}
        drr.select(cands, {"a": 1.0, "b": 0.05}, 4)
        got_b += 8 - len(cands["b"])
    # w=0.05 accrues a whole token every 20 rounds: >= 1 slot in 40 rounds
    assert got_b >= 1


def test_drr_idle_queue_does_not_bank_credit():
    drr = DeficitRoundRobin()
    # b idle for many rounds while a drains
    for _ in range(10):
        drr.select({"a": [1, 2, 3, 4], "b": []}, {"a": 1.0, "b": 1.0}, 2)
    assert drr.deficits["b"] == 0.0
    # when b shows up it competes from zero, not with 10 banked tokens
    cands = {"a": list(range(8)), "b": list(range(8))}
    drr.select(cands, {"a": 1.0, "b": 1.0}, 4)
    assert 8 - len(cands["b"]) <= 3


def test_ladder_escalates_on_breach_streak_and_recovers_with_hysteresis():
    t = Tenant(
        "t", slo=TenantSLO(p99_latency=0.01, min_samples=2),
        ladder=LadderConfig(breach_evals=2, recover_evals=3),
    )

    def window(lat):
        for _ in range(4):
            t.observe(lat, missed=False)

    window(1.0)
    assert t.evaluate() is None and t.level == 0  # one breach != a streak
    window(1.0)
    assert t.evaluate() == (0, 1) and t.level_name == "shrink_flush"
    window(1.0)  # streak resets after a move: two more breaches to escalate
    assert t.evaluate() is None
    window(1.0)
    assert t.evaluate() == (1, 2)
    # recovery is slower than escalation (hysteresis): 3 in-SLO evals
    for _ in range(2):
        window(0.001)
        assert t.evaluate() is None and t.level == 2
    window(0.001)
    assert t.evaluate() == (2, 1)
    assert t.stats["ladder_up"] == 2 and t.stats["ladder_down"] == 1


def test_ladder_undersized_window_holds_streaks():
    t = Tenant(
        "t", slo=TenantSLO(p99_latency=0.01, min_samples=8),
        ladder=LadderConfig(breach_evals=1),
    )
    t.observe(1.0, missed=True)
    assert t.evaluate() is None and t.level == 0  # 1 < min_samples: skipped
    assert t.window_completed == 1  # window carries over, not discarded


def test_ladder_miss_rate_target():
    slo = TenantSLO(max_miss_rate=0.25)
    assert slo.breached(p99=0.0, miss_rate=0.5)
    assert not slo.breached(p99=99.0, miss_rate=0.1)  # p99 target unset


# --------------------------------------------------------------------------- #
# server integration: quotas, fair share, ladder                               #
# --------------------------------------------------------------------------- #


def test_submit_requires_registered_tenant(sr):
    server = _server(sr)
    with pytest.raises(KeyError, match="unknown tenant"):
        server.submit("sr", _frames(1)[0], tenant="nope")
    server.close()


def test_quota_throttles_and_refills_on_engine_clock(sr):
    now = [0.0]
    server = _server(sr, clock=lambda: now[0])
    server.add_tenant("metered", rate=10.0, burst=2.0)
    f = _frames(1)[0]
    server.submit("sr", f, tenant="metered")
    server.submit("sr", f, tenant="metered")
    with pytest.raises(QuotaExceededError):
        server.submit("sr", f, tenant="metered")
    assert server.stats["per_tenant"]["metered"]["throttled"] == 1
    now[0] = 0.1  # one token refilled
    server.submit("sr", f, tenant="metered")
    # QuotaExceededError is a QueueFullError: submit_with_retry rides it
    # out across the refill instead of failing the caller
    def sleep(dt):
        now[0] += max(dt, 0.1)

    h = submit_with_retry(
        server, "sr", f, tenant="metered", backoff=0.1, sleep=sleep,
    )
    assert h.tenant == "metered"
    assert server.stats["per_tenant"]["metered"]["submitted"] == 4
    server.close()


def test_weighted_fair_share_under_joint_backlog(sr):
    """Two backlogged tenants at 3:1 weight split each full batch 3:1 --
    the hot tenant cannot monopolize slots however deep its queue."""
    server = _server(sr)
    server.add_tenant("gold", weight=3.0)
    server.add_tenant("free", weight=1.0)
    f = _frames(1)[0]
    for _ in range(16):
        server.submit("sr", f, tenant="gold")
    for _ in range(16):
        server.submit("sr", f, tenant="free")
    server.step()  # one full batch of 4
    done = {"gold": 0, "free": 0}
    for h in server.drain_completed():
        done[h.tenant] += 1
    assert done == {"gold": 3, "free": 1}
    for _ in range(3):
        server.step()
    per_tenant = server.stats["per_tenant"]
    assert per_tenant["gold"]["completed"] == 12
    assert per_tenant["free"]["completed"] == 4
    server.close()


def _breach_once(server, now, tenant, latency=1.0, n=4):
    """Complete one window of slow requests for ``tenant`` and advance the
    engine clock past the next SLO evaluation."""
    fs = _frames(n)
    hs = [server.submit("sr", f, priority=1, tenant=tenant) for f in fs]
    now[0] += latency
    server.step()  # full batch (n == batch_size); latency == `latency`
    for h in hs:
        h.result(0)
    now[0] += 10.0  # past next_eval
    server.step()  # evaluation tick


def test_ladder_escalation_shrinks_flush_then_demotes_then_sheds(sr):
    go, plan = sr
    now = [0.0]
    server = _server(sr, clock=lambda: now[0], flush_after=1.0)
    server.add_tenant(
        "t", slo=TenantSLO(p99_latency=0.01, min_samples=2),
        ladder=LadderConfig(
            interval=1.0, breach_evals=1, recover_evals=2,
            shrink_factor=0.25, shed_below_priority=1,
        ),
    )
    server.register_variant("sr", "cheap", plan, go.params)
    server.step()  # arms next_eval
    reg = metrics.registry()

    _breach_once(server, now, "t")
    assert server.health()["tenants"]["t"]["level_name"] == "shrink_flush"
    assert reg.gauge("serving_ladder_level", tenant="t").value == 1
    # rung 1: the tenant's partial batch releases after 0.25 * flush_after
    h = server.submit("sr", _frames(1)[0], priority=1, tenant="t")
    now[0] += 0.26
    assert server.step() == 1 and h.done()

    _breach_once(server, now, "t")
    assert server.health()["tenants"]["t"]["level_name"] == "demote_plan"
    # rung 2: new admissions route to the registered cheap variant
    h = server.submit("sr", _frames(1)[0], priority=1, tenant="t")
    assert h._runner.variant == "cheap"
    assert server.stats["per_tenant"]["t"]["demoted_admissions"] == 1
    now[0] += 0.26
    server.step()
    h.result(0)

    _breach_once(server, now, "t")
    assert server.health()["tenants"]["t"]["level_name"] == "shed"
    # rung 3: priority classes below the threshold are turned away...
    with pytest.raises(LadderShedError):
        server.submit("sr", _frames(1)[0], priority=0, tenant="t")
    # ...while higher classes still land (and still demote)
    h = server.submit("sr", _frames(1)[0], priority=1, tenant="t")
    assert h._runner.variant == "cheap"
    now[0] += 0.26
    server.step()

    # recovery: the first window still holds the slow rung-3 probe request
    # (one more breach, but the ladder is already at its top rung); the two
    # clean in-SLO windows after it walk one rung back down
    for _ in range(3):
        _breach_once(server, now, "t", latency=0.0)
    assert server.health()["tenants"]["t"]["level"] == 2
    ups = reg.label_counts(
        "serving_ladder_transitions_total", "tenant", "direction"
    )
    assert ups.get("t/up") == 3.0 and ups.get("t/down") == 1.0
    st = server.stats["per_tenant"]["t"]
    assert st["ladder_shed"] == 1 and st["ladder_up"] == 3
    server.close()


# --------------------------------------------------------------------------- #
# versioned hot-swap                                                           #
# --------------------------------------------------------------------------- #


def test_swap_plan_zero_loss_and_drain_retire(sr):
    """Requests queued before the swap finish on v0, admissions after it
    run on v1, nothing is lost, and v0 retires once drained."""
    go, plan = sr
    server = _server(sr)
    scaled = _scale_params(go.params, 2.0)
    fs = _frames(6)
    old_hs = [server.submit("sr", f) for f in fs[:2]]  # partial batch on v0
    v1 = server.swap_plan("sr", plan, scaled, probe_frames=[fs[0]])
    assert v1 == 1
    health = server.health()
    assert health["plans"]["sr"]["version"] == 1
    assert health["plans"]["sr"]["draining"] == [
        {"version": 0, "outstanding": 2}
    ]
    new_hs = [server.submit("sr", f) for f in fs[2:]]  # v1 traffic
    while server.step(force=True):
        pass
    for h in old_hs + new_hs:
        h.result(0)  # zero loss: every admitted request resolved
    np.testing.assert_allclose(  # v0 work ran on v0 params...
        np.asarray(old_hs[0].result(0)),
        np.asarray(plan(go.params, fs[0][None]))[0], rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_allclose(  # ...post-swap work on v1 params
        np.asarray(new_hs[0].result(0)),
        np.asarray(plan(scaled, fs[2][None]))[0], rtol=1e-5, atol=1e-5,
    )
    s = server.stats
    assert s["swaps"] == 1 and s["versions_retired"] == 1
    assert "draining" not in server.health()["plans"]["sr"]
    assert metrics.registry().label_counts(
        "serving_swap_total", "plan", "event"
    ) == {"sr/installed": 1.0, "sr/retired": 1.0}
    server.close()


def test_swap_plan_failed_probe_rolls_back(sr):
    go, plan = sr
    server = _server(sr)
    h = server.submit("sr", _frames(1)[0])
    poisoned = _scale_params(go.params, np.nan)
    with pytest.raises(SwapError, match="non-finite"):
        server.swap_plan("sr", plan, poisoned)
    # rollback: v0 is still primary and still serves
    assert server.health()["plans"]["sr"]["version"] == 0
    assert server.stats["swap_rollbacks"] == 1
    server.step(force=True)
    h.result(0)
    server.close()


def test_swap_plan_parity_gate_rolls_back_drifting_version(sr):
    go, plan = sr
    server = _server(sr)
    scaled = _scale_params(go.params, 2.0)
    with pytest.raises(SwapError, match="drifts"):
        server.swap_plan(
            "sr", plan, scaled, probe_frames=[_frames(1)[0]],
            parity_tol=1e-6,
        )
    assert server.health()["plans"]["sr"]["version"] == 0
    server.close()


def test_swap_probe_uses_input_spec_when_no_probe_frames(sr):
    go, plan = sr
    server = AsyncPlanServer(clock=lambda: 0.0)
    server.add_plan(
        "sr", plan, go.params, batch_size=4,
        input_spec=[(FRAME, jnp.float32)],
    )
    assert server.swap_plan("sr", plan, go.params) == 1  # zeros probe
    server.close()


def test_swap_without_spec_or_frames_refuses(sr):
    go, plan = sr
    server = _server(sr)  # no input_spec, no traffic yet: spec unknown
    with pytest.raises(SwapError, match="unprobed"):
        server.swap_plan("sr", plan, go.params)
    server.close()


def test_register_variant_rejects_duplicates_and_unknown_plan(sr):
    go, plan = sr
    server = _server(sr)
    server.register_variant("sr", "cheap", plan, go.params)
    with pytest.raises(ValueError, match="already registered"):
        server.register_variant("sr", "cheap", plan, go.params)
    with pytest.raises(KeyError):
        server.register_variant("nope", "cheap", plan, go.params)
    server.close()


# --------------------------------------------------------------------------- #
# property-based fairness                                                      #
# --------------------------------------------------------------------------- #


@settings(max_examples=12, deadline=None)
@given(
    w_hot=st.floats(1.0, 8.0),
    hot_per_round=st.integers(4, 12),
    light_per_round=st.integers(1, 4),
)
def test_fair_share_bounds_hot_tenant_and_never_starves_light(
    w_hot, hot_per_round, light_per_round
):
    """Pure-DRR property: under any skewed arrival pattern and weight, each
    backlogged tenant's completed share tracks its weight share within one
    round's granularity per batch, and the light tenant never starves."""
    drr = DeficitRoundRobin()
    weights = {"hot": w_hot, "light": 1.0}
    queues = {"hot": [], "light": []}
    done = {"hot": 0, "light": 0}
    slots, rounds = 4, 32
    for r in range(rounds):
        queues["hot"] += [("hot", r)] * hot_per_round
        queues["light"] += [("light", r)] * light_per_round
        for name, _ in drr.select(queues, weights, slots):
            done[name] += 1
    total = done["hot"] + done["light"]
    assert total == slots * rounds  # offered >= capacity every round
    assert done["light"] >= 1  # no starvation, no matter the skew
    # while both stay backlogged, shares track weights; the light tenant's
    # backlog can run dry (small arrival rate), which only ever shifts
    # slots toward hot -- so bound the LIGHT share from below against the
    # rounds it had work queued, +/- one slot per round of granularity
    light_share = 1.0 / (w_hot + 1.0)
    light_offered = light_per_round * rounds
    entitled = min(light_offered, light_share * total)
    assert done["light"] >= entitled - rounds
    # and hot must not exceed capacity minus what light actually consumed
    assert done["hot"] == total - done["light"]


# --------------------------------------------------------------------------- #
# satellites: retry delegation                                                 #
# --------------------------------------------------------------------------- #


def test_submit_with_retry_delegates_to_shared_retry_call(monkeypatch):
    """One backoff implementation in the repo: submit_with_retry must route
    through utils.retry.retry_call, not grow a private copy."""
    import repro.serving.scheduler as sched

    calls = {}

    def fake_retry_call(fn, **kw):
        calls.update(kw)
        return "handle"

    monkeypatch.setattr(sched, "retry_call", fake_retry_call)

    class _Server:
        def submit(self, *a, **kw):  # pragma: no cover - never reached
            raise AssertionError

    out = sched.submit_with_retry(
        _Server(), "sr", retries=7, backoff=0.25, jitter=0.0,
    )
    assert out == "handle"
    assert calls["retries"] == 7 and calls["backoff"] == 0.25
    assert calls["retry_on"] == (sched.QueueFullError,)
