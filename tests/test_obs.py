"""Observability subsystem (`repro.obs`): metrics-registry semantics
(types, label pinning, bounded reservoirs, exporters, state transplant),
structured tracing (span nesting with an injectable clock, Chrome-trace
validity, the disabled-mode fast path), the plan profiler, and the wiring
through the executor / pass manager / serving scheduler -- per-step spans
match plan step count for every demo app, and a serving trace links every
completed request to exactly one macro-batch span."""

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import compile_plan, optimize
from repro.core.graph.pass_manager import PassManager
from repro.models.cnn import APPS, app_masks
from repro.obs import metrics, profile_plan, trace
from repro.obs.metrics import MetricsRegistry
from repro.serving import AsyncPlanServer

KEY = jax.random.PRNGKey(0)


def _plan(app="super_resolution", backend="reference"):
    g = APPS[app](KEY, base=8)
    masks, structures = app_masks(g, app, sparsity=0.5)
    go = optimize(g, masks, structures)
    return go, compile_plan(go, backend=backend)


def _frame(app, i=0, size=8):
    c = 1 if app == "coloring" else 3
    return jax.random.normal(jax.random.PRNGKey(i), (c, size, size))


# --------------------------------------------------------------------------- #
# metrics registry                                                             #
# --------------------------------------------------------------------------- #


def test_counter_semantics():
    r = MetricsRegistry()
    c = r.counter("hits_total", op="conv2d")
    c.inc()
    c.inc(4)
    assert c.value == 5
    # same (name, labels) resolves to the same series
    assert r.counter("hits_total", op="conv2d").value == 5
    assert r.counter("hits_total", op="linear").value == 0
    with pytest.raises(ValueError):
        c.inc(-1)  # counters are monotonic


def test_gauge_set_max_keeps_high_water():
    r = MetricsRegistry()
    g = r.gauge("queue_peak", plan="sr")
    g.set_max(3)
    g.set_max(1)  # lower: ignored
    assert g.value == 3
    g.set(0.5)  # plain set overwrites
    assert g.value == 0.5
    g.add(2)
    assert g.value == 2.5


def test_histogram_reservoir_is_bounded_but_totals_exact():
    r = MetricsRegistry()
    h = r.histogram("lat_ms", reservoir=100, plan="sr")
    for i in range(1000):
        h.observe(float(i))
    assert h.count == 1000  # exact over every observation
    assert h.sum == sum(range(1000))
    # percentiles come from the most recent 100 observations only
    assert h.percentile(0) >= 900
    assert h.percentile(100) == 999
    s = h.stats()
    assert s["count"] == 1000 and 900 <= s["p50"] <= 999
    assert s["p95"] >= s["p50"] and s["p99"] >= s["p95"]


def test_type_collision_raises():
    r = MetricsRegistry()
    r.counter("x_total")
    with pytest.raises(ValueError, match="one name, one type"):
        r.gauge("x_total")
    with pytest.raises(ValueError, match="one name, one type"):
        r.histogram("x_total")


def test_label_names_pinned_per_family():
    r = MetricsRegistry()
    r.counter("y_total", op="conv2d", scheme="w8")
    # same names, different values: fine (new series)
    r.counter("y_total", op="linear", scheme="f32").inc()
    with pytest.raises(ValueError, match="pinned"):
        r.counter("y_total", op="conv2d")  # missing a label name
    with pytest.raises(ValueError, match="pinned"):
        r.counter("y_total", op="conv2d", backend="kernel", scheme="w8")


def test_label_counts_view_matches_legacy_shape():
    r = MetricsRegistry()
    r.counter("demote_total", op="conv2d", scheme="w8", reason="numeric").inc(2)
    r.counter("demote_total", op="linear", scheme="f32", reason="exception").inc()
    assert r.label_counts("demote_total", "op", "scheme", "reason") == {
        "conv2d/w8/numeric": 2.0,
        "linear/f32/exception": 1.0,
    }
    assert r.label_counts("unknown_total", "op") == {}


def test_snapshot_json_and_prometheus_exports():
    r = MetricsRegistry()
    r.counter("req_total", help="requests", plan="sr").inc(3)
    r.gauge("depth", plan="sr").set(2)
    h = r.histogram("lat_s", plan='s"r\n')  # exporter must escape this
    h.observe(1.0)
    h.observe(3.0)
    snap = json.loads(r.to_json())
    assert snap["req_total"]["type"] == "counter"
    assert snap["req_total"]["samples"][0] == {
        "labels": {"plan": "sr"}, "value": 3.0,
    }
    hs = snap["lat_s"]["samples"][0]
    assert hs["count"] == 2 and hs["sum"] == 4.0 and hs["p50"] == 2.0
    text = r.to_prometheus()
    assert '# TYPE req_total counter' in text
    assert 'req_total{plan="sr"} 3' in text
    assert '# TYPE lat_s summary' in text
    assert 'lat_s_count{plan="s\\"r\\n"} 2' in text
    assert 'quantile="0.5"' in text
    assert '# HELP req_total requests' in text


def test_dump_load_state_roundtrip_is_exact():
    r = MetricsRegistry()
    r.counter("a_total", k="v").inc(7)
    r.histogram("b_ms", reservoir=8).observe(1.5)
    state = r.dump_state()
    r.counter("a_total", k="v").inc()  # diverge
    r.counter("c_total").inc()  # new family
    r.load_state(state)
    assert r.counter("a_total", k="v").value == 7
    assert "c_total" not in r.names()
    assert r.dump_state() == state
    # the dump is a deep copy: mutating the registry never changes it
    r.histogram("b_ms", reservoir=8).observe(9.9)
    assert state["b_ms"]["series"][()]["reservoir"] == [1.5]


def test_reset_family_keeps_type_pinned():
    r = MetricsRegistry()
    r.counter("z_total", op="a").inc()
    r.reset("z_total")
    assert r.label_counts("z_total", "op") == {}
    with pytest.raises(ValueError):
        r.gauge("z_total")  # family survived: type still pinned


# --------------------------------------------------------------------------- #
# tracing                                                                      #
# --------------------------------------------------------------------------- #


def test_span_nesting_with_injected_clock():
    t = [0.0]

    def clock():
        t[0] += 0.001  # 1ms per clock read
        return t[0]

    with trace.tracing(clock) as buf:
        with trace.span("outer", cat="t") as outer:
            with trace.span("inner", cat="t"):
                pass
            outer.set("k", "v")
    spans = buf.spans()
    assert [s["name"] for s in spans] == ["outer", "inner"]
    outer_s, inner_s = spans
    # B(outer)=1ms B(inner)=2ms E(inner)=3ms E(outer)=4ms
    assert outer_s["dur"] == pytest.approx(3000.0)
    assert inner_s["dur"] == pytest.approx(1000.0)
    assert inner_s["ts"] > outer_s["ts"]
    assert inner_s["ts"] + inner_s["dur"] <= outer_s["ts"] + outer_s["dur"]
    assert outer_s["args"] == {"k": "v"}  # set() lands on the begin event


def test_chrome_trace_validity_phases_pair_and_timestamps_monotonic():
    with trace.tracing() as buf:
        with trace.span("a"):
            trace.instant("mark", cat="g", why="test")
        with trace.span("b"):
            pass
    doc = buf.chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert json.loads(json.dumps(doc)) == doc  # JSON-serializable as-is
    ts = [ev["ts"] for ev in events]
    assert ts == sorted(ts)  # single-threaded: strictly append-ordered
    assert {ev["ph"] for ev in events} == {"B", "E", "i"}
    assert all({"name", "ph", "pid", "tid", "ts"} <= set(ev) for ev in events)
    buf.spans()  # pairs up: no exception


def test_unbalanced_trace_is_detected():
    buf = trace.TraceBuffer()
    buf.add({"name": "x", "cat": "t", "ph": "B", "pid": 1, "tid": 1, "ts": 0.0,
             "args": {}})
    with pytest.raises(ValueError, match="unclosed"):
        buf.spans()
    buf2 = trace.TraceBuffer()
    buf2.add({"name": "x", "ph": "E", "pid": 1, "tid": 1, "ts": 0.0})
    with pytest.raises(ValueError, match="empty stack"):
        buf2.spans()


def test_span_error_annotated():
    with trace.tracing() as buf:
        with pytest.raises(RuntimeError):
            with trace.span("boom"):
                raise RuntimeError("x")
    (sp,) = buf.spans()
    assert sp["args"]["error"] == "RuntimeError"


def test_disabled_mode_is_allocation_free_and_inert():
    assert not trace.enabled()
    s1 = trace.span("a", op="x")
    s2 = trace.span("b")
    assert s1 is s2 is trace.NULL_SPAN  # one shared singleton, no allocation
    with s1 as sp:
        sp.set("k", "v")  # no-op, no error
    trace.instant("never")
    trace.async_begin("never", 1)
    trace.async_end("never", 1)
    assert trace.current_buffer() is None


def test_tracing_context_restores_previous_session():
    outer = trace.start_tracing()
    try:
        trace.instant("outer-1")
        with trace.tracing() as inner:
            trace.instant("inner-1")
            assert trace.current_buffer() is inner
        assert trace.current_buffer() is outer  # nested session composes
        trace.instant("outer-2")
        assert [e["name"] for e in outer.instants()] == ["outer-1", "outer-2"]
        assert [e["name"] for e in inner.instants()] == ["inner-1"]
    finally:
        trace.stop_tracing()


def test_async_events_cross_thread_ids():
    with trace.tracing() as buf:
        trace.async_begin("request", 7, cat="serving", plan="sr")

        def worker():
            trace.async_instant("request", 7, cat="serving", phase="batched")

        th = threading.Thread(target=worker)
        th.start()
        th.join()
        trace.async_end("request", 7, cat="serving")
    evs = buf.async_events("request")
    assert [e["ph"] for e in evs] == ["b", "n", "e"]
    assert {e["id"] for e in evs} == {"7"}  # one logical op across threads
    assert len({e["tid"] for e in evs}) == 2


# --------------------------------------------------------------------------- #
# executor / pass-manager wiring                                               #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("app", sorted(APPS))
def test_per_step_spans_match_plan_step_count(app):
    go, plan = _plan(app)
    x = _frame(app)[None]
    with trace.tracing() as buf:
        y = plan(go.params, x)
    steps = [s for s in buf.spans() if s["cat"] == "step"]
    assert len(steps) == len(plan.steps)
    assert [s["name"] for s in steps] == [st.node.name for st in plan.steps]
    for s in steps:
        assert s["args"]["backend"] == "reference"
        assert s["args"]["op"]
        assert s["args"]["out_shape"]
    (plan_span,) = [s for s in buf.spans() if s["cat"] == "plan"]
    assert plan_span["args"]["steps"] == len(plan.steps)
    # parity: the traced run computes exactly what the untraced run does
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(plan(go.params, x)), rtol=1e-6, atol=1e-6
    )


def test_untraced_run_emits_nothing():
    go, plan = _plan("coloring")
    with trace.tracing() as buf:
        pass  # session closed before the run
    plan(go.params, _frame("coloring")[None])
    assert len(buf) == 0


def test_pass_manager_emits_per_pass_spans():
    g = APPS["coloring"](KEY, base=8)
    masks, structures = app_masks(g, "coloring", sparsity=0.5)
    from repro.core.graph.pass_manager import PassContext

    pm = PassManager()
    with trace.tracing() as buf:
        pm.run(g, PassContext(masks=masks, structures=structures))
    passes = [s for s in buf.spans() if s["cat"] == "pass"]
    # skipped passes (needs_calibration without a table) emit no span
    assert [s["name"] for s in passes] == [
        p.name for p in pm.passes if p.name != "quantize"
    ]
    for s in passes:
        assert s["args"]["nodes_before"] >= s["args"]["nodes_after"] or True
        assert "changed" in s["args"]


def test_guard_demotions_hit_registry_and_spans():
    from repro.core.graph import guard_fallback_counts
    from repro.robustness import FaultPlan, FaultRule

    go, plan = _plan("coloring", backend="guarded")
    x = _frame("coloring")[None]
    before = sum(guard_fallback_counts().values())
    with FaultPlan([FaultRule("conv2d", "raise", rate=1.0)]):
        with trace.tracing() as buf:
            plan(go.params, x)
    # registry view: demotions counted under op/scheme/reason
    counts = guard_fallback_counts()
    n_conv = sum(v for k, v in counts.items() if k.startswith("conv2d/"))
    assert n_conv >= 1 and sum(counts.values()) > before
    # span annotations: demoted steps carry the reason + a guard instant
    demoted = [
        s for s in buf.spans()
        if s["cat"] == "step" and s["args"].get("demoted")
    ]
    assert len(demoted) >= 1
    # first few steps demote on the raised fault; once the breaker trips,
    # the rest demote pre-emptively with breaker_open
    reasons = {s["args"]["demoted"] for s in demoted}
    assert "exception" in reasons
    assert reasons <= {"exception", "breaker_open"}
    instants = buf.instants("guard")
    assert len(instants) == len(demoted)  # one guard instant per demoted step
    assert all(i["name"].startswith("demote:") for i in instants)
    assert [i["args"]["reason"] for i in instants] == [
        s["args"]["demoted"] for s in demoted
    ]


def test_conv_fallback_counts_are_registry_views():
    from repro.kernels import ops as kops

    x = jnp.ones((1, 4, 6, 6))
    w = jnp.ones((4, 2, 3, 3))
    kops.conv2d(x, w, groups=2, interpret=True)
    assert kops.conv_fallback_counts().get("groups", 0) >= 1
    raw = metrics.registry().label_counts("conv_fallback_total", "reason")
    assert raw.get("groups", 0) >= 1
    kops.reset_conv_fallbacks()
    assert kops.conv_fallback_counts() == {}


# --------------------------------------------------------------------------- #
# profiler                                                                     #
# --------------------------------------------------------------------------- #


def test_profile_plan_rows_match_steps():
    go, plan = _plan("super_resolution")
    x = _frame("super_resolution")[None]
    prof = profile_plan(plan, go.params, x, runs=2, warmup=1)
    assert prof.backend == "reference"
    assert len(prof.steps) == len(plan.steps)
    assert prof.runs == 2
    assert prof.total_ms > 0
    assert sum(s.pct for s in prof.steps) == pytest.approx(100.0)
    for row, st in zip(prof.steps, plan.steps):
        assert row.name == st.node.name and row.op == st.node.op
        assert row.ms >= 0 and row.bytes_moved > 0
        assert row.attribution == "reference"
        assert row.out_shape
    text = prof.render_text(top=3)
    assert "plan profile" in text and text.count("\n") == 4  # header+head+3
    blob = json.dumps(prof.to_json())
    assert json.loads(blob)["backend"] == "reference"
    # the profiler restores the caller's tracing state (off)
    assert not trace.enabled()


def test_profile_plan_trace_is_valid_chrome_trace(tmp_path):
    go, plan = _plan("coloring")
    prof = profile_plan(plan, go.params, _frame("coloring")[None], runs=1)
    p = prof.trace.save(str(tmp_path / "t.json"))
    doc = json.load(open(p))
    assert doc["displayTimeUnit"] == "ms"
    steps = [s for s in prof.trace.spans() if s["cat"] == "step"]
    assert len(steps) == len(plan.steps)  # one span per plan step


# --------------------------------------------------------------------------- #
# serving wiring                                                               #
# --------------------------------------------------------------------------- #


def _sr_server(**kw):
    go, plan = _plan("super_resolution")
    server = AsyncPlanServer(clock=kw.pop("clock", lambda: 0.0), **kw)
    server.add_plan("sr", plan, go.params, batch_size=2)
    return server


def test_serving_trace_links_requests_to_exactly_one_batch():
    server = _sr_server()
    with trace.tracing() as buf:
        handles = [
            server.submit("sr", _frame("super_resolution", i)) for i in range(6)
        ]
        while server.step():
            pass
        assert all(h.done() for h in handles)
        server.close()
    batch_spans = [s for s in buf.spans() if s["name"] == "batch"]
    assert len(batch_spans) == 3  # 6 requests / batch_size 2
    # every rid appears in exactly one batch span's membership
    rid_to_batch = {}
    for s in batch_spans:
        for rid in s["args"]["rids"]:
            assert rid not in rid_to_batch
            rid_to_batch[rid] = s["args"]["batch"]
    assert sorted(rid_to_batch) == [h.rid for h in handles]
    # and the request's own async events agree with the batch that served it
    for h in handles:
        evs = buf.async_events("request")
        mine = [e for e in evs if e["id"] == str(h.rid)]
        phases = [e["ph"] for e in mine]
        assert phases == ["b", "n", "e"]  # submit -> batched -> completed
        batched = [e for e in mine if e["ph"] == "n"][0]
        done = [e for e in mine if e["ph"] == "e"][0]
        assert batched["args"]["batch"] == rid_to_batch[h.rid]
        assert done["args"]["phase"] == "completed"
        assert done["args"]["deadline_missed"] is False


def test_serving_stats_mirrored_into_registry():
    server = _sr_server()
    for i in range(4):
        server.submit("sr", _frame("super_resolution", i))
    while server.step():
        pass
    server.close()
    events = metrics.registry().label_counts(
        "serving_events_total", "plan", "event"
    )
    assert events["sr/submitted"] == 4
    assert events["sr/completed"] == 4
    assert events["sr/batches"] == 2
    lat = metrics.registry().histogram("serving_latency_seconds", plan="sr")
    assert lat.count == 4
    peak = metrics.registry().gauge("serving_queue_depth_peak", plan="sr")
    assert peak.value == 4  # all four queued before the first tick
    assert server.health()["plans"]["sr"]["queue_peak"] == 4


def test_shed_request_ends_its_trace_span():
    server = _sr_server(max_queue=1, overload="shed")
    with trace.tracing() as buf:
        h1 = server.submit("sr", _frame("super_resolution", 0))
        h2 = server.submit(
            "sr", _frame("super_resolution", 1), priority=1
        )  # evicts h1
        evs = [e for e in buf.async_events("request") if e["id"] == str(h1.rid)]
        assert [e["ph"] for e in evs] == ["b", "e"]
        assert evs[-1]["args"]["phase"] == "shed"
        server.step(force=True)
        server.close()
    assert h2.done()
