"""Compile pipeline v2: PassManager ordering/invariants, elementwise fusion
exactness, execution-plan parity with lower() on the three demo apps, buffer
liveness, and the kernel block-size tuning cache."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import (
    ExecutionPlan,
    GraphBuilder,
    GraphPass,
    InvariantViolation,
    PassContext,
    PassManager,
    available_passes,
    compile_plan,
    cse,
    fuse_elementwise,
    lower,
    optimize,
)
from repro.core.graph.ir import Graph, Node
from repro.kernels import ops as kops
from repro.models.cnn import APPS, app_masks

KEY = jax.random.PRNGKey(0)

APP_INPUTS = {
    "style_transfer": (1, 3, 16, 16),
    "coloring": (1, 1, 16, 16),
    "super_resolution": (1, 3, 8, 8),
}


# --------------------------------------------------------------------------- #
# PassManager                                                                  #
# --------------------------------------------------------------------------- #


def _identity_graph():
    b = GraphBuilder(["x"])
    h = b.add("linear", "x", name="l1", params={"w": jnp.eye(8)})
    return b.build(h)


def test_pass_manager_runs_in_declared_order():
    ran = []

    def mk(name):
        def fn(g, ctx):
            ran.append(name)
            return g

        return GraphPass(name=name, fn=fn)

    pm = PassManager([mk("a"), mk("b"), mk("c")])
    ctx = PassContext()
    pm.run(_identity_graph(), ctx)
    assert ran == ["a", "b", "c"]
    assert list(ctx.stats) == ["a", "b", "c"]


def test_pass_manager_unknown_pass_raises():
    with pytest.raises(KeyError, match="unknown pass"):
        PassManager(["definitely_not_registered"])


def test_registry_contains_default_pipeline():
    for name in ("fold_norm", "fuse_activation", "substitute_sparse",
                 "fold_gathers", "cse", "fuse_elementwise", "dce"):
        assert name in available_passes()


def test_pass_manager_validates_between_stages():
    def breaker(g, ctx):  # duplicate a node name -> structurally invalid
        return Graph(
            nodes=list(g.nodes) + [g.nodes[0]],
            inputs=g.inputs,
            outputs=g.outputs,
            params=g.params,
        )

    pm = PassManager([GraphPass(name="breaker", fn=breaker)])
    with pytest.raises(InvariantViolation, match="duplicate"):
        pm.run(_identity_graph(), PassContext())


def test_pass_manager_post_invariant_enforced():
    def bad_post(g, ctx):
        raise InvariantViolation("declared post failed")

    pm = PassManager([GraphPass(name="noop", fn=lambda g, ctx: g, post=(bad_post,))])
    with pytest.raises(InvariantViolation, match="declared post"):
        pm.run(_identity_graph(), PassContext())


def test_mask_passes_skipped_without_masks():
    ctx = PassContext()  # no masks
    g = PassManager().run(_identity_graph(), ctx)
    s = ctx.stats["substitute_sparse"]
    assert s.nodes_before == s.nodes_after and not s.changed
    assert [n.op for n in g.nodes] == ["linear"]


def test_optimize_is_thin_wrapper_with_custom_pipeline():
    g = _identity_graph()
    go = optimize(g, pipeline=("dce",))
    assert [n.name for n in go.nodes] == ["l1"]


# --------------------------------------------------------------------------- #
# elementwise fusion + cse                                                     #
# --------------------------------------------------------------------------- #


def _elementwise_chain_graph():
    b = GraphBuilder(["x", "y"])
    l1 = b.add("linear", "x", name="l1",
               params={"w": jax.random.normal(KEY, (16, 16)) * 0.1})
    h = b.add("add", (l1, "y"), name="a1")
    h = b.add("mul", (h, "y"), name="m1")
    h = b.add("activation", h, name="act1", fn="gelu")
    h = b.add("norm", h, name="ln1", kind="layer",
              params={"scale": jnp.ones(16) * 1.3, "bias": jnp.ones(16) * 0.2})
    return b.build(h)


def test_fuse_elementwise_exactness_vs_unfused():
    g = _elementwise_chain_graph()
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    y = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    ref = lower(g, use_kernels=False)(g.params, x, y)
    gf = fuse_elementwise(g)
    ops = [n.op for n in gf.nodes]
    assert ops == ["linear", "fused_elementwise"], ops
    assert gf.nodes[-1].name == "ln1"  # chain tail keeps its name
    got = lower(gf, use_kernels=False)(gf.params, x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6, atol=1e-6)


def test_fuse_elementwise_respects_fanout():
    b = GraphBuilder(["x"])
    a1 = b.add("activation", "x", name="a1", fn="relu")
    a2 = b.add("activation", a1, name="a2", fn="tanh")
    a3 = b.add("activation", a1, name="a3", fn="gelu")  # a1 has 2 consumers
    out = b.add("add", (a2, a3), name="out")
    g = b.build(out)
    gf = fuse_elementwise(g)
    # a1 must survive unfused; a2/a3 are single-node "chains" (not fused)
    assert "a1" in [n.name for n in gf.nodes]
    x = jax.random.normal(KEY, (2, 8))
    np.testing.assert_allclose(
        np.asarray(lower(gf, use_kernels=False)(gf.params, x)),
        np.asarray(lower(g, use_kernels=False)(g.params, x)),
        rtol=1e-6,
    )


def test_cse_dedupes_identical_nodes():
    b = GraphBuilder(["x"])
    a1 = b.add("activation", "x", name="dup1", fn="relu")
    a2 = b.add("activation", "x", name="dup2", fn="relu")
    out = b.add("add", (a1, a2), name="out")
    g = b.build(out)
    g2 = cse(g)
    assert len(g2.nodes) == 2  # one relu + the add
    x = jax.random.normal(KEY, (2, 8))
    np.testing.assert_array_equal(
        np.asarray(lower(g2, use_kernels=False)(g2.params, x)),
        np.asarray(lower(g, use_kernels=False)(g.params, x)),
    )


def test_cse_keeps_distinct_attrs():
    b = GraphBuilder(["x"])
    a1 = b.add("activation", "x", name="r", fn="relu")
    a2 = b.add("activation", "x", name="t", fn="tanh")
    out = b.add("add", (a1, a2), name="out")
    g = cse(b.build(out))
    assert len(g.nodes) == 3


# --------------------------------------------------------------------------- #
# execution plans                                                              #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("app", list(APPS))
def test_plan_matches_lower_on_pruned_apps(app):
    """lower(g)(params, x) must equal the plan-based executor bit-exactly."""
    g = APPS[app](KEY, base=16)
    masks, structures = app_masks(g, app, sparsity=0.5)
    go = optimize(g, masks, structures)
    x = jax.random.normal(jax.random.PRNGKey(1), APP_INPUTS[app])
    y_lower = lower(go, use_kernels=False)(go.params, x)
    plan = compile_plan(go, backend="reference")
    assert isinstance(lower(go, use_kernels=False), ExecutionPlan)
    y_plan = plan(go.params, x)
    np.testing.assert_array_equal(np.asarray(y_lower), np.asarray(y_plan))


def test_plan_schedule_is_topological_and_liveness_sound():
    g = APPS["coloring"](KEY, base=16)
    go = optimize(g)
    plan = compile_plan(go, backend="reference")
    defined = set(go.inputs)
    freed = set()
    for step in plan.steps:
        for i in step.node.inputs:
            assert i in defined and i not in freed, (step.node.name, i)
        defined.add(step.node.name)
        freed.update(step.frees)
    # everything except outputs/inputs dies somewhere; outputs never freed
    assert not (freed & set(go.outputs)) and not (freed & set(go.inputs))
    consumed = {i for s in plan.steps for i in s.node.inputs}
    expected_dead = {
        n.name for n in go.nodes
        if n.name in consumed and n.name not in go.outputs
    }
    assert freed == expected_dead


def test_plan_handles_out_of_order_node_list():
    n1 = Node(op="activation", name="a", inputs=("l",), attrs={"fn": "relu"})
    n2 = Node(op="linear", name="l", inputs=("x",))
    g = Graph(nodes=[n1, n2], inputs=("x",), outputs=("a",),
              params={"l": {"w": jnp.eye(4)}})
    plan = compile_plan(g, backend="reference")  # schedules l before a
    assert [s.node.name for s in plan.steps] == ["l", "a"]
    x = jnp.ones((2, 4))
    np.testing.assert_array_equal(np.asarray(plan(g.params, x)), np.asarray(jnp.ones((2, 4))))


def test_plan_unknown_op_fails_at_compile_time():
    g = Graph(nodes=[Node(op="martian_conv", name="m", inputs=("x",))],
              inputs=("x",), outputs=("m",))
    with pytest.raises(NotImplementedError, match="martian_conv"):
        compile_plan(g, backend="reference")


def test_plan_memory_estimate():
    g = APPS["super_resolution"](KEY, base=16)
    go = optimize(g)
    plan = compile_plan(go, backend="reference")
    x = jax.ShapeDtypeStruct(APP_INPUTS["super_resolution"], jnp.float32)
    mem = plan.memory_estimate(x)
    out = mem["out_structs"][0]
    assert out.shape == (1, 3, 16, 16)
    biggest = max(b for _, b, _ in mem["per_step"])
    assert mem["peak_activation_bytes"] >= biggest > 0
    assert mem["peak_total_bytes"] == mem["peak_activation_bytes"] + mem["param_bytes"]


def test_plan_jits_and_matches_eager():
    g = APPS["style_transfer"](KEY, base=16)
    go = optimize(g)
    plan = compile_plan(go, backend="reference")
    x = jax.random.normal(KEY, APP_INPUTS["style_transfer"])
    np.testing.assert_allclose(
        np.asarray(jax.jit(plan)(go.params, x)),
        np.asarray(plan(go.params, x)),
        rtol=1e-5, atol=1e-5,
    )


# --------------------------------------------------------------------------- #
# tuning cache                                                                 #
# --------------------------------------------------------------------------- #


@pytest.fixture
def fresh_cache():
    cache = kops.tuning_cache()
    prev_enabled, prev_entries, prev_sweeps = cache.enabled, dict(cache.entries), cache.sweeps
    cache.clear()
    yield cache
    cache.enabled = prev_enabled
    cache.entries = prev_entries
    cache.sweeps = prev_sweeps


def test_tuning_disabled_uses_seeded_default_without_sweep(fresh_cache):
    fresh_cache.enabled = False
    x = jax.random.normal(KEY, (16, 64)) * 0.1
    w = jax.random.normal(KEY, (64, 32)) * 0.1
    y = kops.matmul(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-4, atol=1e-4)
    assert fresh_cache.sweeps == 0
    entry = fresh_cache.entries[kops.TuningCache.key("matmul", 16, 32, 64, jnp.float32, "dense", True)]
    assert entry.source == "default"


def test_tuning_sweeps_once_then_hits_cache(fresh_cache, monkeypatch):
    fresh_cache.enabled = True
    monkeypatch.setitem(
        kops.TuningCache.CANDIDATES, "matmul", ((128, 128, 128), (64, 128, 128))
    )
    x = jax.random.normal(KEY, (16, 64)) * 0.1
    w = jax.random.normal(KEY, (64, 32)) * 0.1
    kops.matmul(x, w)
    assert fresh_cache.sweeps == 1
    kops.matmul(x, w)
    assert fresh_cache.sweeps == 1, "cache hit must skip the sweep"
    key = kops.TuningCache.key("matmul", 16, 32, 64, jnp.float32, "dense", True)
    assert fresh_cache.entries[key].source == "swept"


def test_tuning_cache_json_roundtrip(fresh_cache, tmp_path):
    fresh_cache.entries[kops.TuningCache.key("matmul", 8, 8, 8, jnp.float32, "dense", True)] = (
        kops.TuneEntry((64, 128, 128), "swept", 1.25)
    )
    p = tmp_path / "tune.json"
    fresh_cache.save(str(p))
    payload = json.loads(p.read_text())
    assert payload["version"] == 1
    c2 = kops.TuningCache(enabled=False)
    c2.load(str(p))
    assert c2.lookup("matmul", 8, 8, 8, jnp.float32, "dense", True) == (64, 128, 128)
    assert next(iter(c2.entries.values())).source == "loaded"


def test_matmul_consults_cached_blocks(fresh_cache, monkeypatch):
    fresh_cache.enabled = False
    key = kops.TuningCache.key("matmul", 16, 32, 64, jnp.float32, "dense", True)
    fresh_cache.entries[key] = kops.TuneEntry((64, 256, 128), "loaded")
    seen = {}
    real = kops._dense_matmul

    def spy(x, w, b, **kw):
        seen.update(kw)
        return real(x, w, b, **kw)

    monkeypatch.setattr(kops, "_dense_matmul", spy)
    x = jax.random.normal(KEY, (16, 64)) * 0.1
    w = jax.random.normal(KEY, (64, 32)) * 0.1
    kops.matmul(x, w)
    assert (seen["block_m"], seen["block_n"], seen["block_k"]) == (64, 256, 128)


def test_tuning_never_sweeps_under_jit(fresh_cache):
    fresh_cache.enabled = True
    x = jax.random.normal(KEY, (16, 64)) * 0.1
    w = jax.random.normal(KEY, (64, 32)) * 0.1
    y = jax.jit(lambda a, b: kops.matmul(a, b))(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-4, atol=1e-4)
    assert fresh_cache.sweeps == 0


def test_default_entry_does_not_poison_later_sweep(fresh_cache, monkeypatch):
    """A shape first seen under jit (default recorded) must still be tuned
    once concrete arrays show up with tuning enabled -- and seeded defaults
    must not be persisted (they are placeholders, not measurements)."""
    fresh_cache.enabled = True
    monkeypatch.setitem(
        kops.TuningCache.CANDIDATES, "matmul", ((128, 128, 128), (64, 128, 128))
    )
    x = jax.random.normal(KEY, (16, 64)) * 0.1
    w = jax.random.normal(KEY, (64, 32)) * 0.1
    jax.jit(lambda a, b: kops.matmul(a, b))(x, w)  # records a default entry
    key = kops.TuningCache.key("matmul", 16, 32, 64, jnp.float32, "dense", True)
    assert fresh_cache.entries[key].source == "default"
    kops.matmul(x, w)  # concrete: the placeholder must be re-tuned
    assert fresh_cache.sweeps == 1
    assert fresh_cache.entries[key].source == "swept"


def test_save_skips_default_entries(fresh_cache, tmp_path):
    fresh_cache.entries["a|1x1x1|float32|dense"] = kops.TuneEntry((128, 128, 128), "default")
    fresh_cache.entries["b|2x2x2|float32|dense"] = kops.TuneEntry((64, 128, 128), "swept", 1.0)
    p = tmp_path / "t.json"
    fresh_cache.save(str(p))
    saved = json.loads(p.read_text())["entries"]
    assert list(saved) == ["b|2x2x2|float32|dense"]


def test_tuning_key_separates_interpret_from_hardware_mode(fresh_cache):
    """Interpret-mode sweeps time Python, not silicon: their winners must
    never shadow (or be shadowed by) real-hardware entries."""
    ki = kops.TuningCache.key("matmul", 8, 8, 8, jnp.float32, "dense", True)
    kh = kops.TuningCache.key("matmul", 8, 8, 8, jnp.float32, "dense", False)
    assert ki != kh
    fresh_cache.entries[ki] = kops.TuneEntry((64, 128, 128), "swept", 1.0)
    assert fresh_cache.lookup("matmul", 8, 8, 8, jnp.float32, "dense", False) is None


def test_memory_estimate_falls_back_for_kernel_only_ops():
    from repro.core.graph import executor, register_op

    op = "kernel_only_test_op"
    try:
        register_op(op, backends=("kernel",))(lambda p, xs, a, rt: xs[0] * 2.0)
        g = Graph(nodes=[Node(op=op, name="m", inputs=("x",))],
                  inputs=("x",), outputs=("m",))
        plan = compile_plan(g, backend="kernel")
        mem = plan.memory_estimate(jax.ShapeDtypeStruct((2, 4), jnp.float32))
        assert mem["out_structs"][0].shape == (2, 4)
    finally:
        executor._HANDLERS["kernel"].pop(op, None)


def test_partially_pinned_blocks_use_defaults_not_cache(fresh_cache, monkeypatch):
    fresh_cache.enabled = False
    key = kops.TuningCache.key("matmul", 16, 32, 64, jnp.float32, "dense", True)
    fresh_cache.entries[key] = kops.TuneEntry((256, 256, 256), "loaded")
    seen = {}
    real = kops._dense_matmul

    def spy(x, w, b, **kw):
        seen.update(kw)
        return real(x, w, b, **kw)

    monkeypatch.setattr(kops, "_dense_matmul", spy)
    x = jax.random.normal(KEY, (16, 64)) * 0.1
    w = jax.random.normal(KEY, (64, 32)) * 0.1
    kops.matmul(x, w, block_m=64)  # pinned m, free n/k -> defaults, not cache
    assert (seen["block_m"], seen["block_n"], seen["block_k"]) == (64, 128, 128)
