"""Shared fixtures.  NOTE: no XLA_FLAGS here -- smoke tests must see ONE
device (the dry-run sets its own 512-device flag in a separate process)."""

import jax
import pytest

from repro.core.graph import executor as _executor  # noqa: F401 (re-export)
from repro.kernels import ops as kops
from repro.obs import metrics as _metrics
from repro.obs import trace as _otrace
from repro.robustness import faults as _faults


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


# --------------------------------------------------------------------------- #
# global-state isolation                                                       #
# --------------------------------------------------------------------------- #
#
# Process-level mutable state leaks between tests if left alone: the
# metrics registry (conv fallback/fastpath counters, guard demotions,
# serving mirrors all live there now), the tracing switch + buffer, and the
# block-size TuningCache singleton (entries, enabled flag, sweep counter,
# save path).  The autouse fixture below snapshots all of it around EVERY
# test so no test can observe another's mutations -- the order-independence
# regression lives in tests/test_state_isolation.py, which drives these
# helpers directly.


def snapshot_global_state():
    """Capture the process-level kernel/obs state a test could mutate."""
    cache = kops.tuning_cache()
    return {
        "metrics": _metrics.registry().dump_state(),  # deep copy
        "trace": _otrace.state(),
        "tune_entries": dict(cache.entries),
        "tune_enabled": cache.enabled,
        "tune_sweeps": cache.sweeps,
        "tune_path": cache.path,
        "tune_ops_filter": cache.ops_filter,
        "tune_stats": {op: dict(s) for op, s in cache.stats.items()},
    }


def restore_global_state(snap) -> None:
    """Reset the process-level kernel/obs state to ``snap`` (exact contents,
    not a merge: entries/counters/metric families added since the snapshot
    are discarded, and the tracing switch goes back to its prior setting).
    Any FaultPlan a test left installed is force-uninstalled first, so a
    failing chaos test can never leak patched kernel entry points."""
    _faults.uninstall_all()
    _metrics.registry().load_state(snap["metrics"])
    _otrace.restore(snap["trace"])
    cache = kops.tuning_cache()
    cache.entries = dict(snap["tune_entries"])
    cache.enabled = snap["tune_enabled"]
    cache.sweeps = snap["tune_sweeps"]
    cache.path = snap["tune_path"]
    cache.ops_filter = snap["tune_ops_filter"]
    cache.stats = {op: dict(s) for op, s in snap["tune_stats"].items()}


@pytest.fixture(autouse=True)
def _isolate_global_state():
    """Every test runs against the kernel state it started with: fallback
    counters and the process TuningCache are restored on exit, so test
    outcomes cannot depend on execution order (or on -n auto scheduling)."""
    snap = snapshot_global_state()
    try:
        yield
    finally:
        restore_global_state(snap)
