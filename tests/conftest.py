"""Shared fixtures.  NOTE: no XLA_FLAGS here -- smoke tests must see ONE
device (the dry-run sets its own 512-device flag in a separate process)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
