"""Property-based differential suite: kernel backend vs reference oracle.

Strategies draw random shapes, schemes and epilogue step programs and
assert the Pallas kernel wrappers (``kernels/ops.py``) agree with their
pure-jnp oracles (``kernels/ref.py``) -- the same split the executor's
``kernel``/``reference`` backends are built on, so any divergence here is a
serving-visible correctness bug.  With hypothesis installed these are real
property tests; without it, ``tests/_hypothesis_fallback.py`` degrades each
``@given`` to a deterministic boundary+midpoint sweep, so the suite always
runs in minimal containers (and in CI both ways).

Shapes deliberately straddle the kernels' tiling boundaries: below one tile,
non-multiples of the 8x128 f32 tile, and just past a block edge -- the pad/
slice seams where tiled kernels historically break.
"""

import zlib

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised in minimal containers
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops as kops
from repro.kernels import ref as kref

SETTINGS = dict(max_examples=16, deadline=None, derandomize=True)

#: kernel-local epilogue step programs (slots index the generated sides);
#: norm-free programs run in-tile for matmul/qmatmul/conv2d, the norm ones
#: exercise fused_elementwise's row-statistics path
EPILOGUES = (
    (),
    (("activation", "relu"),),
    (("add", 0), ("activation", "gelu")),
    (("mul", 0), ("add", 1)),
)


def _key(*dims) -> jax.Array:
    """Deterministic per-example data: seed from the drawn parameters (via
    crc32 -- ``hash()`` is salted per process) so every (shrunk) failing
    example reproduces bit-identically."""
    return jax.random.PRNGKey(zlib.crc32(repr(dims).encode()) % (2**31))


def _sides(n_slots, shape, seed):
    return [
        jax.random.normal(jax.random.fold_in(seed, 10 + i), shape)
        for i in range(n_slots)
    ]


def _n_slots(program):
    return max((s[1] + 1 for s in program if s[0] in ("add", "mul")), default=0)


# --------------------------------------------------------------------------- #
# matmul                                                                       #
# --------------------------------------------------------------------------- #


@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 7, 130]),
    k=st.sampled_from([8, 33]),
    n=st.sampled_from([16, 129]),
    bias=st.booleans(),
    program=st.sampled_from(EPILOGUES),
)
def test_matmul_matches_reference(m, k, n, bias, program):
    key = _key("matmul", m, k, n, bias, program)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
    b = jax.random.normal(jax.random.fold_in(key, 2), (n,)) if bias else None
    sides = _sides(_n_slots(program), (m, n), key)
    got = kops.matmul(
        x, w, b, epilogue=program, epilogue_sides=sides, interpret=True
    )
    want = kref.apply_steps_ref(kref.matmul_ref(x, w, b), program, sides)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


# --------------------------------------------------------------------------- #
# qmatmul (W8 / W8A8)                                                          #
# --------------------------------------------------------------------------- #


@settings(**SETTINGS)
@given(
    m=st.sampled_from([3, 9]),
    k=st.sampled_from([16, 40]),
    n=st.sampled_from([32, 130]),
    w8a8=st.booleans(),
    bias=st.booleans(),
)
def test_qmatmul_matches_reference(m, k, n, w8a8, bias):
    from repro.quant import QTensor

    key = _key("qmatmul", m, k, n, w8a8, bias)
    x = jax.random.normal(key, (m, k)) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.05
    b = jax.random.normal(jax.random.fold_in(key, 2), (n,)) if bias else None
    qt = QTensor.from_float(w, axis=1)
    x_scale = float(jnp.max(jnp.abs(x))) / 127.0 if w8a8 else None
    got = kops.qmatmul(x, qt.values, qt.scale, b, x_scale=x_scale, interpret=True)
    want = kref.qmatmul_ref(x, qt.values, qt.scale, b, x_scale=x_scale)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


# --------------------------------------------------------------------------- #
# conv2d (dense f32; stride / padding / filter-size seams)                     #
# --------------------------------------------------------------------------- #


@settings(**SETTINGS)
@given(
    c=st.sampled_from([3, 8]),
    hw=st.sampled_from([6, 9]),
    o=st.sampled_from([8, 17]),
    ksize=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
)
def test_conv2d_matches_reference(c, hw, o, ksize, stride, padding):
    key = _key("conv2d", c, hw, o, ksize, stride, padding)
    x = jax.random.normal(key, (1, c, hw, hw)) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 1), (o, c, ksize, ksize)) * 0.1
    b = jax.random.normal(jax.random.fold_in(key, 2), (o,)) * 0.1
    got = kops.conv2d(x, w, b, stride=stride, padding=padding, interpret=True)
    want = kref.conv2d_ref(x, w, b, stride=stride, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


# --------------------------------------------------------------------------- #
# flash attention (causal prefill + kv_lengths paged-decode masking)           #
# --------------------------------------------------------------------------- #


@settings(**SETTINGS)
@given(
    h=st.sampled_from([1, 4]),
    s=st.sampled_from([5, 128, 130]),
    scale=st.booleans(),
)
def test_flash_attention_causal_matches_reference(h, s, scale):
    key = _key("flash_causal", h, s, scale)
    b, dh = 2, 16
    q = jax.random.normal(key, (b, h, s, dh)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, dh)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, dh))
    sc = 0.3 if scale else None
    got = kops.attention(q, k, v, causal=True, scale=sc, interpret=True)
    want = kref.flash_attention_ref(q, k, v, causal=True, scale=sc)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


@settings(**SETTINGS)
@given(
    h=st.sampled_from([1, 4]),
    sq=st.sampled_from([1, 7]),
    skv=st.sampled_from([9, 128, 130]),
    lens_kind=st.sampled_from(["one", "mid", "full"]),
)
def test_flash_attention_kv_lengths_matches_reference(h, sq, skv, lens_kind):
    """The paged-KV masking path: Skv is a gathered page span, kv_lengths
    marks each row's live prefix.  Slots past the length (zero-filled pages,
    block padding) must never attract probability mass."""
    key = _key("flash_lens", h, sq, skv, lens_kind)
    b, dh = 2, 16
    q = jax.random.normal(key, (b, h, sq, dh)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, skv, dh)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, skv, dh))
    lens = {
        "one": jnp.asarray([1, 1], jnp.int32),
        "mid": jnp.asarray([skv // 2, skv - 1], jnp.int32),
        "full": jnp.asarray([skv, 3], jnp.int32),
    }[lens_kind]
    got = kops.attention(q, k, v, lens, causal=False, interpret=True)
    want = kref.flash_attention_ref(q, k, v, lens, causal=False)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )
    # and the values past each row's length genuinely do not matter
    k2 = k.at[0, :, int(lens[0]):, :].set(1e3)
    v2 = v.at[0, :, int(lens[0]):, :].set(-1e3)
    got2 = kops.attention(q, k2, v2, lens, causal=False, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got2)[0], np.asarray(got)[0], rtol=1e-5, atol=1e-5
    )


# --------------------------------------------------------------------------- #
# fused_ffn (gate/up GEMM pair + glu activation, the decoder FFN fast path)    #
# --------------------------------------------------------------------------- #


@settings(**SETTINGS)
@given(
    m=st.sampled_from([3, 128, 130]),
    k=st.sampled_from([8, 33]),
    f=st.sampled_from([16, 129]),
    activation=st.sampled_from(["silu", "gelu", "relu"]),
)
def test_ffn_gateup_matches_reference(m, k, f, activation):
    key = _key("ffn_gateup", m, k, f, activation)
    x = jax.random.normal(key, (m, k)) * 0.5
    wg = jax.random.normal(jax.random.fold_in(key, 1), (k, f)) * 0.1
    wu = jax.random.normal(jax.random.fold_in(key, 2), (k, f)) * 0.1
    got = kops.ffn_gateup(x, wg, wu, activation=activation, interpret=True)
    want = kref.ffn_gateup_ref(x, wg, wu, activation=activation)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


# --------------------------------------------------------------------------- #
# fused_elementwise (whole step programs, incl. layer-norm statistics)         #
# --------------------------------------------------------------------------- #

FUSED_PROGRAMS = EPILOGUES[1:] + (
    (("activation", "gelu"), ("add", 0), ("norm", 0, 1e-5)),
)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([3, 10]),
    d=st.sampled_from([5, 128, 200]),
    program=st.sampled_from(FUSED_PROGRAMS),
)
def test_fused_elementwise_matches_reference(m, d, program):
    key = _key("fused_elementwise", m, d, program)
    x = jax.random.normal(key, (m, d))
    sides = _sides(_n_slots(program), (m, d), key)
    norms = [
        (
            jax.random.normal(jax.random.fold_in(key, 20), (d,)) * 0.1 + 1.0,
            jax.random.normal(jax.random.fold_in(key, 21), (d,)) * 0.1,
        )
        for _ in range(sum(s[0] == "norm" for s in program))
    ]
    got = kops.fused_elementwise(x, sides, program, norms, interpret=True)
    want = kref.fused_elementwise_ref(x, sides, program, norms)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )
