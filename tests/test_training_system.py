"""System behaviour: train loop (loss decreases), ADMM phases, checkpoint
save/restore/resume, data determinism, fault-tolerance plumbing, serving."""

import dataclasses
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.pruning import AdmmConfig, tree_sparsity_report, hard_prune
from repro.data.pipeline import PipelineState, SyntheticPipeline
from repro.models import get_model
from repro.serving.engine import Engine, Request, RequestScheduler
from repro.training.checkpoint import CheckpointManager, restore, save
from repro.training.fault_tolerance import PreemptionHandler, StragglerMonitor, retry
from repro.training.optimizer import AdamWConfig, cosine_schedule
from repro.training.train_loop import TrainState, init_train_state, make_train_step
from repro.launch.train import default_prune_plan

KEY = jax.random.PRNGKey(0)


def _setup(arch="qwen2.5-3b", steps=40, lr=2e-3, prune=False, accum=1):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    pipe = SyntheticPipeline(cfg, batch=8, seq=33, seed=0)
    opt_cfg = AdamWConfig(lr=lr, total_steps=steps, warmup_steps=2)
    admm_cfg = AdmmConfig(rho=1e-2, update_every=5) if prune else None
    plan = default_prune_plan(0.5) if prune else None
    params = model.init(KEY)
    state = init_train_state(params, opt_cfg, admm_cfg=admm_cfg, prune_plan=plan)
    step = jax.jit(make_train_step(model.loss, opt_cfg, admm_cfg=admm_cfg, accum=accum))
    return cfg, model, pipe, opt_cfg, state, step


def test_train_loss_decreases():
    cfg, model, pipe, opt_cfg, state, step = _setup(steps=30)
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        state, m = step(state, batch)
        losses.append(float(m["ce"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[:3] + losses[-3:]


def test_grad_accumulation_matches_full_batch():
    cfg, model, pipe, opt_cfg, state, _ = _setup()
    batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
    step1 = jax.jit(make_train_step(model.loss, opt_cfg, accum=1))
    step4 = jax.jit(make_train_step(model.loss, opt_cfg, accum=4))
    s1, m1 = step1(state, batch)
    s4, m4 = step4(state, batch)
    # same mean gradient -> same updated params (up to accum-order fp noise)
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        s1.params, s4.params,
    )
    assert max(jax.tree.leaves(d)) < 5e-3


def test_admm_full_pipeline_prunes_and_recovers():
    cfg, model, pipe, opt_cfg, state, step = _setup(steps=40, prune=True)
    for _ in range(20):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        state, m = step(state, batch)
    assert state.admm is not None and float(m["primal_residual"]) > 0
    pruned, masks = hard_prune(state.params, state.admm)
    rep = tree_sparsity_report(pruned, masks)
    assert rep["pruned_global"] == pytest.approx(0.5, abs=0.05)
    # masked fine-tune: sparsity is preserved across steps
    state2 = TrainState(params=pruned, opt=state.opt, admm=None, masks=masks)
    step2 = jax.jit(make_train_step(model.loss, opt_cfg))
    for _ in range(5):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        state2, m2 = step2(state2, batch)
    rep2 = tree_sparsity_report(state2.params, masks)
    assert rep2["pruned_global"] == pytest.approx(rep["pruned_global"], abs=1e-6)


# --------------------------------------------------------------------------- #
# checkpointing                                                                #
# --------------------------------------------------------------------------- #


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg, model, pipe, opt_cfg, state, step = _setup(steps=20)
    mgr = CheckpointManager(str(tmp_path), save_every=5, keep=2)
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        state, _ = step(state, batch)
        mgr.maybe_save(i + 1, (state, pipe.state.to_dict()))
    # keep=2: only the last two checkpoints remain
    from repro.training.checkpoint import all_steps

    assert all_steps(str(tmp_path)) == [5, 10]
    (restored, data_state), at = mgr.restore_latest((state, pipe.state.to_dict()))
    assert at == 10 and int(data_state["data_step"]) == 10
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(jnp.asarray(a, jnp.float32) - jnp.asarray(b, jnp.float32)).max()),
        jax.tree.leaves(restored.params), jax.tree.leaves(state.params),
    )
    assert max(jax.tree.leaves(d)) == 0.0

    # resumed run == uninterrupted run (exact determinism)
    pipe_b = SyntheticPipeline(cfg, batch=8, seq=33, seed=0)
    pipe_b.state = PipelineState.from_dict(data_state)
    state_b = restored
    for _ in range(5):
        batch = {k: jnp.asarray(v) for k, v in pipe_b.next().items()}
        state_b, _ = step(state_b, batch)
    state_a = state
    for _ in range(5):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        state_a, _ = step(state_a, batch)
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(jnp.asarray(a, jnp.float32) - jnp.asarray(b, jnp.float32)).max()),
        state_a.params, state_b.params,
    )
    assert max(jax.tree.leaves(d)) == 0.0


def test_checkpoint_atomicity(tmp_path):
    """A truncated tmp dir never shadows the last good checkpoint."""
    tree = {"w": jnp.ones((4, 4))}
    save(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_000000002.tmp")  # simulated dead write
    from repro.training.checkpoint import latest_step

    assert latest_step(str(tmp_path)) == 1
    restored, at = restore(str(tmp_path), tree)
    assert at == 1


def test_checkpoint_rejects_shape_mismatch(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.ones((4, 4))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"w": jnp.ones((8, 4))})


# --------------------------------------------------------------------------- #
# data pipeline                                                                #
# --------------------------------------------------------------------------- #


def test_data_determinism_and_sharding():
    cfg = smoke_config("qwen2.5-3b")
    a = SyntheticPipeline(cfg, batch=8, seq=16, seed=3)
    b = SyntheticPipeline(cfg, batch=8, seq=16, seed=3)
    for _ in range(3):
        ba, bb = a.next(), b.next()
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    # host shards tile the global batch exactly
    g = a.global_batch(7)
    parts = [a.host_shard(g, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), g["tokens"])
    # labels are the shifted tokens
    np.testing.assert_array_equal(g["tokens"][:, 1:], g["labels"][:, :-1])


def test_data_is_learnable_structure():
    """Markov stream: bigram statistics are far from uniform."""
    cfg = smoke_config("qwen2.5-3b")
    pipe = SyntheticPipeline(cfg, batch=32, seq=64, seed=0)
    toks = pipe.next()["tokens"]
    # successor entropy given prev token must be far below log2(vocab)
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    branching = np.mean([len(set(v)) for v in pairs.values() if len(v) >= 3])
    assert branching < cfg.vocab / 8


# --------------------------------------------------------------------------- #
# fault tolerance                                                              #
# --------------------------------------------------------------------------- #


def test_preemption_handler_flags_signal():
    with PreemptionHandler() as h:
        os.kill(os.getpid(), signal.SIGTERM)
        assert h.should_stop and h.received == signal.SIGTERM
    # handler restored afterwards
    assert signal.getsignal(signal.SIGTERM) != h._handler


def test_retry_recovers_transients():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return 42

    assert retry(flaky, retries=5, backoff=0.001) == 42
    assert calls["n"] == 3


def test_straggler_monitor_detects():
    import time

    mon = StragglerMonitor(threshold=2.0, window=10)
    for _ in range(6):
        mon.start_step()
        time.sleep(0.02)
        mon.end_step()
    mon.start_step()
    time.sleep(0.25)
    mon.end_step()
    assert len(mon.straggler_steps) == 1


# --------------------------------------------------------------------------- #
# serving                                                                      #
# --------------------------------------------------------------------------- #


def test_engine_generate_greedy_deterministic():
    cfg = smoke_config("qwen2.5-3b")
    model = get_model(cfg)
    params = model.init(KEY)
    eng = Engine(model, params, batch_size=2, max_len=64)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    r1 = eng.generate(prompts, 6)
    r2 = eng.generate(prompts, 6)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.tokens.shape == (2, 6)
    assert (r1.tokens < cfg.vocab).all(), "pad classes must never be sampled"


def test_engine_generate_matches_stepwise_forward():
    """Greedy generation == argmax over teacher-forced forward logits."""
    import repro.models.transformer as lm

    cfg = smoke_config("granite-3-2b")
    model = get_model(cfg)
    params = model.init(KEY)
    eng = Engine(model, params, batch_size=1, max_len=32)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab)
    out = eng.generate(prompts, 4).tokens[0]
    seq = list(np.asarray(prompts[0]))
    for t in range(4):
        logits, _ = lm.forward(params, cfg, jnp.asarray([seq], jnp.int32))
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == out[t]
        seq.append(nxt)


def test_request_scheduler_completes_queue():
    cfg = smoke_config("qwen2.5-3b")
    model = get_model(cfg)
    params = model.init(KEY)
    eng = Engine(model, params, batch_size=2, max_len=48)
    sched = RequestScheduler(eng)
    rng = np.random.default_rng(0)
    for rid in range(5):
        sched.submit(Request(rid=rid, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32), max_new=3))
    sched.run(max_ticks=200)
    done = [r for r in sched.slots if r is not None] + sched.queue
    assert all(r.done for r in sched.slots if r is not None)
    assert not sched.queue  # everything admitted


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(cosine_schedule(jnp.asarray(s), cfg)) for s in (0, 9, 10, 50, 99)]
    assert lrs[0] < lrs[1] <= 1.0 + 1e-6
    assert lrs[-1] == pytest.approx(0.1, abs=0.02)
