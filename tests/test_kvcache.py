"""Property suite for the block-table paged KV-cache (`serving/kvcache.py`).

Three families of invariants lock the cache in:

1. **Pool conservation** -- random alloc/append/release programs never leak
   or double-assign pages (``check_invariants`` after every op, freelist
   fully restored once every sequence is released).
2. **Gather fidelity** -- the block-table gather returns exactly the
   appended tokens in order (even when sequences grew interleaved so their
   pages are scattered through the pool), and attention over a gathered
   span with ``kv_lengths`` masking is bit-identical to attention over a
   contiguous per-sequence cache.
3. **Failure atomicity** -- ``ensure_capacity`` past the pool is
   all-or-nothing: the block table, freelist, and existing data survive a
   ``CacheFullError`` unchanged.
"""

import zlib

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised in minimal containers
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ref as kref
from repro.serving import CacheFullError, PagedKVCache

SETTINGS = dict(max_examples=16, deadline=None, derandomize=True)

SPEC = dict(n_layers=2, n_kv_heads=2, head_dim=4)


def _rng(*dims) -> np.random.Generator:
    return np.random.default_rng(zlib.crc32(repr(dims).encode()) % (2**31))


def _tokens(rng, t):
    shape = (t, SPEC["n_layers"], SPEC["n_kv_heads"], SPEC["head_dim"])
    return (
        rng.standard_normal(shape).astype(np.float32),
        rng.standard_normal(shape).astype(np.float32),
    )


# --------------------------------------------------------------------------- #
# 1. pool conservation under random programs                                   #
# --------------------------------------------------------------------------- #


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 7),
    num_pages=st.sampled_from([4, 9]),
    page_size=st.sampled_from([1, 3]),
)
def test_random_program_never_leaks_pages(seed, num_pages, page_size):
    rng = _rng("program", seed, num_pages, page_size)
    cache = PagedKVCache(num_pages=num_pages, page_size=page_size, **SPEC)
    mirror: dict[int, list] = {}  # sid -> [(k, v), ...] appended chunks
    next_sid = 0
    for _ in range(40):
        op = rng.choice(["alloc", "append", "release", "gather"])
        if op == "alloc":
            cache.allocate(next_sid)
            mirror[next_sid] = []
            next_sid += 1
        elif op == "append" and mirror:
            sid = int(rng.choice(list(mirror)))
            t = int(rng.integers(1, 2 * page_size + 2))
            k, v = _tokens(rng, t)
            before = cache.block_table(sid)
            try:
                cache.append(sid, k, v)
                mirror[sid].append((k, v))
            except CacheFullError:
                # all-or-nothing: the table must be untouched
                assert cache.block_table(sid) == before
        elif op == "release" and mirror:
            sid = int(rng.choice(list(mirror)))
            freed = cache.release(sid)
            assert freed == cache.pages_for(
                sum(k.shape[0] for k, _ in mirror[sid])
            )
            del mirror[sid]
        elif op == "gather" and mirror:
            sids = list(mirror)
            k_ctx, v_ctx, lens = cache.gather(sids)
            for j, sid in enumerate(sids):
                want_len = sum(k.shape[0] for k, _ in mirror[sid])
                assert int(lens[j]) == want_len
                if want_len:
                    want_k = np.concatenate([k for k, _ in mirror[sid]])
                    want_v = np.concatenate([v for _, v in mirror[sid]])
                    # gather is [B, L, S, G, dh]; mirror is token-major
                    np.testing.assert_array_equal(
                        k_ctx[j, :, :want_len].swapaxes(0, 1), want_k
                    )
                    np.testing.assert_array_equal(
                        v_ctx[j, :, :want_len].swapaxes(0, 1), want_v
                    )
        cache.check_invariants()
    for sid in list(mirror):
        cache.release(sid)
    cache.check_invariants()
    assert cache.free_pages == num_pages and not cache.sequences()


# --------------------------------------------------------------------------- #
# 2. gather == contiguous cache, through attention                             #
# --------------------------------------------------------------------------- #


def test_paged_gather_attention_matches_contiguous():
    """Grow three sequences interleaved so their pages scatter through the
    pool, then check masked attention over the gathered spans is bit-equal
    to attention over each sequence's contiguous KV."""
    rng = _rng("gather_attn")
    cache = PagedKVCache(num_pages=12, page_size=3, **SPEC)
    dense: dict[int, list] = {}
    for sid in range(3):
        cache.allocate(sid)
        dense[sid] = []
    for step in range(5):
        for sid in range(3):
            t = (sid + step) % 3 + 1
            k, v = _tokens(rng, t)
            cache.append(sid, k, v)
            dense[sid].append((k, v))
    cache.check_invariants()
    # interleaved growth => at least one block table is non-contiguous
    tables = [cache.block_table(s) for s in range(3)]
    assert any(
        any(b - a != 1 for a, b in zip(tb, tb[1:])) for tb in tables
    ), tables

    k_ctx, v_ctx, lens = cache.gather([0, 1, 2])
    g, dh = SPEC["n_kv_heads"], SPEC["head_dim"]
    q = _rng("gather_q").standard_normal((3, g, 1, dh)).astype(np.float32)
    for layer in range(SPEC["n_layers"]):
        # paged path: full zero-padded span, masked by kv_lengths
        got = kref.flash_attention_ref(
            jnp.asarray(q),
            jnp.asarray(k_ctx[:, layer].swapaxes(1, 2)),
            jnp.asarray(v_ctx[:, layer].swapaxes(1, 2)),
            jnp.asarray(lens),
            causal=False,
        )
        for sid in range(3):
            k_d = np.concatenate([k for k, _ in dense[sid]])[:, layer]
            v_d = np.concatenate([v for _, v in dense[sid]])[:, layer]
            want = kref.flash_attention_ref(
                jnp.asarray(q[sid : sid + 1]),
                jnp.asarray(k_d.swapaxes(0, 1)[None]),
                jnp.asarray(v_d.swapaxes(0, 1)[None]),
                causal=False,
            )
            np.testing.assert_array_equal(
                np.asarray(got[sid]), np.asarray(want[0])
            )


def test_page_reuse_after_release_is_clean():
    """Pages handed back and re-acquired serve the new owner's tokens, and
    the LIFO freelist hands the hottest pages out first."""
    cache = PagedKVCache(num_pages=4, page_size=2, **SPEC)
    rng = _rng("reuse")
    cache.allocate(0)
    k0, v0 = _tokens(rng, 4)
    cache.append(0, k0, v0)
    old_pages = cache.block_table(0)
    assert cache.release(0) == 2
    cache.allocate(1)
    k1, v1 = _tokens(rng, 3)
    cache.append(1, k1, v1)
    assert set(cache.block_table(1)) <= set(old_pages)  # LIFO reuse
    k_ctx, v_ctx, lens = cache.gather([1])
    assert int(lens[0]) == 3
    np.testing.assert_array_equal(k_ctx[0, :, :3].swapaxes(0, 1), k1)
    np.testing.assert_array_equal(v_ctx[0, :, :3].swapaxes(0, 1), v1)
    cache.check_invariants()


# --------------------------------------------------------------------------- #
# 3. failure atomicity + API edges                                             #
# --------------------------------------------------------------------------- #


def test_cache_full_is_all_or_nothing():
    cache = PagedKVCache(num_pages=3, page_size=2, **SPEC)
    rng = _rng("full")
    cache.allocate(0)
    k, v = _tokens(rng, 3)
    cache.append(0, k, v)  # 2 pages, 1 free
    table = cache.block_table(0)
    with pytest.raises(CacheFullError):
        cache.ensure_capacity(0, 7)  # needs 2 more, only 1 free
    assert cache.block_table(0) == table and cache.free_pages == 1
    k_ctx, _, lens = cache.gather([0])
    assert int(lens[0]) == 3
    np.testing.assert_array_equal(k_ctx[0, :, :3].swapaxes(0, 1), k)
    cache.check_invariants()


def test_api_edges():
    cache = PagedKVCache(num_pages=2, page_size=2, **SPEC)
    cache.allocate(0)
    with pytest.raises(ValueError):
        cache.allocate(0)  # double-allocate
    with pytest.raises(ValueError):
        cache.append(0, np.zeros((1, 9, 9, 9), np.float32),
                     np.zeros((1, 9, 9, 9), np.float32))  # bad KV shape
    with pytest.raises(KeyError):
        cache.length(99)
    assert cache.pages_for(0) == 0
    assert cache.pages_for(1) == 1
    assert cache.pages_for(2) == 1
    assert cache.pages_for(3) == 2
    # min_tokens raises the gather span to a page multiple
    k_ctx, _, _ = cache.gather([0], min_tokens=3)
    assert k_ctx.shape[2] == 4
    occ = cache.occupancy()
    assert occ["sequences"] == 1 and occ["used_pages"] == 0
