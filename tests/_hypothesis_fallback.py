"""Deterministic stand-in for hypothesis so tier-1 collection never dies.

When hypothesis is installed the test modules import the real thing; this
fallback turns each ``@given`` into a small deterministic parameter sweep
(bounds + midpoint for ranges, every element for ``sampled_from``).  It covers
exactly the strategy surface the test suite uses: ``integers``, ``floats``,
``sampled_from``, ``booleans``.
"""

from __future__ import annotations

import functools
import inspect
import itertools
from types import SimpleNamespace
from typing import Any, List


class _Strategy:
    def __init__(self, examples: List[Any]):
        self.examples = examples


def _integers(lo: int, hi: int) -> _Strategy:
    mid = (lo + hi) // 2
    return _Strategy(sorted({lo, mid, hi}))


def _floats(lo: float, hi: float) -> _Strategy:
    return _Strategy(sorted({lo, (lo + hi) / 2.0, hi}))


def _sampled_from(seq) -> _Strategy:
    return _Strategy(list(seq))


def _booleans() -> _Strategy:
    return _Strategy([False, True])


st = SimpleNamespace(
    integers=_integers,
    floats=_floats,
    sampled_from=_sampled_from,
    booleans=_booleans,
)


def settings(**_kw):
    def deco(fn):
        return fn

    return deco


def given(*arg_strats: _Strategy, **kw_strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            keys = list(kw_strats)
            pools = [s.examples for s in arg_strats] + [kw_strats[k].examples for k in keys]
            for combo in itertools.product(*pools):
                pos = combo[: len(arg_strats)]
                kw = dict(zip(keys, combo[len(arg_strats) :]))
                fn(*pos, **kw)

        # pytest must see a zero-arg test, not the wrapped signature
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
