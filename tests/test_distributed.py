"""Multi-device tests (subprocess: smoke tests must keep the main process at
ONE device; these re-exec with XLA_FLAGS=--xla_force_host_platform_device_count).

Covers: sharded train step on a small mesh (pjit path used at scale),
gradient compression collective, pipeline parallelism, elastic checkpoint
restore onto a different mesh, and the dry-run machinery itself.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharded_train_step_small_mesh():
    """pjit train step on (2 data, 2 model): loss decreases, params sharded."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.models import get_model
        from repro.models.sharding import param_pspecs
        from repro.data.pipeline import SyntheticPipeline
        from repro.training.optimizer import AdamWConfig
        from repro.training.train_loop import init_train_state, make_train_step

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = smoke_config("qwen2.5-3b")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), param_pspecs(params))
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_cfg = AdamWConfig(lr=2e-3, total_steps=20, warmup_steps=2)
        state = init_train_state(params, opt_cfg)
        step = jax.jit(make_train_step(model.loss, opt_cfg))
        pipe = SyntheticPipeline(cfg, batch=8, seq=33, seed=0)
        with mesh:
            losses = []
            for _ in range(15):
                b = {k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
                     for k, v in pipe.next().items()}
                state, m = step(state, b)
                losses.append(float(m["ce"]))
        assert losses[-1] < losses[0], losses
        # a TP-sharded weight really is distributed
        w = state.params["layers"][0]["ffn"]["w_gate"]["w"]
        assert len(w.sharding.device_set) == 4 or len(w.sharding.device_set) == 2
        print("OK", losses[0], "->", losses[-1])
    """, devices=4)
    assert "OK" in out


def test_compressed_allreduce_multi_device():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.training.compression import (CompressionConfig,
            make_compressed_allreduce)
        mesh = jax.make_mesh((8,), ("data",))
        tmpl = {"w": jnp.zeros((16, 32))}
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 16, 32))}
        err = {"w": jnp.zeros((8, 16, 32))}
        f = make_compressed_allreduce(mesh, tmpl, cfg=CompressionConfig("int8"))
        mean, err2 = f(g, err)
        true = g["w"].mean(0)
        e1 = float(jnp.abs(mean["w"] - true).max())
        assert e1 < 0.05, e1
        mean2, _ = f(g, err2)
        e2 = float(jnp.abs((mean["w"] + mean2["w"]) / 2 - true).max())
        assert e2 < e1, (e1, e2)   # error feedback reduces bias
        # topk policy
        ft = make_compressed_allreduce(mesh, tmpl, cfg=CompressionConfig("topk", topk_frac=0.5))
        meant, _ = ft(g, {"w": jnp.zeros((8, 16, 32))})
        assert float(jnp.abs(meant["w"]).max()) > 0
        print("OK", e1, e2)
    """)
    assert "OK" in out


def test_pipeline_parallel_grad_exactness():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.training.pipeline_parallel import make_pipelined_loss, pipeline_forward
        mesh = jax.make_mesh((4,), ("pipe",))
        L, D, M, mb = 8, 16, 4, 4
        params = {"w": jax.random.normal(jax.random.PRNGKey(2), (L, D, D)) * 0.2}
        layer_fn = lambda lp, h: jnp.tanh(h @ lp["w"])
        x = jax.random.normal(jax.random.PRNGKey(3), (M, mb, D))
        y = jax.random.normal(jax.random.PRNGKey(4), (M, mb, D))
        out = pipeline_forward(layer_fn, params, x, mesh=mesh)
        ref = x
        for i in range(L):
            ref = jnp.tanh(ref @ params["w"][i])
        assert float(jnp.abs(out - ref).max()) < 1e-5
        loss = make_pipelined_loss(layer_fn, lambda o, t: jnp.mean((o - t) ** 2), mesh=mesh)
        g = jax.grad(loss)(params, x, y)
        def ref_loss(p):
            h = x
            for i in range(L):
                h = jnp.tanh(h @ p["w"][i])
            return jnp.mean((h - y) ** 2)
        g_ref = jax.grad(ref_loss)(params)
        assert float(jnp.abs(g["w"] - g_ref["w"]).max()) < 1e-6
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_elastic_checkpoint_restore_other_mesh(tmp_path):
    """Save on a (4 data, 1 model) mesh, restore onto (2 data, 2 model)."""
    out = _run(f"""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.models import get_model
        from repro.models.sharding import param_pspecs
        from repro.training.checkpoint import restore, save

        cfg = smoke_config("granite-3-2b")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh_a = jax.make_mesh((4, 1), ("data", "model"))
        sh_a = jax.tree.map(lambda s: NamedSharding(mesh_a, s), param_pspecs(params))
        params_a = jax.tree.map(jax.device_put, params, sh_a)
        save({str(tmp_path)!r}, 7, params_a)

        mesh_b = jax.make_mesh((2, 2), ("data", "model"))
        sh_b = jax.tree.map(lambda s: NamedSharding(mesh_b, s), param_pspecs(params))
        restored, at = restore({str(tmp_path)!r}, params, shardings=sh_b)
        assert at == 7
        d = jax.tree.map(lambda a, b: float(jnp.abs(jnp.asarray(a, jnp.float32)
                                                    - jnp.asarray(b, jnp.float32)).max()),
                         restored, params)
        assert max(jax.tree.leaves(d)) == 0.0
        w = restored["layers"][0]["ffn"]["w_gate"]["w"]
        assert w.sharding.mesh.shape["model"] == 2
        print("OK")
    """, devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_cell_end_to_end(tmp_path):
    """The actual dry-run driver on one (arch, shape) for both meshes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "granite-3-2b",
         "--shape", "decode_32k", "--mesh", "both", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=1200, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    for mesh in ("single", "multi"):
        with open(tmp_path / f"granite-3-2b__decode_32k__{mesh}.json") as f:
            rec = json.load(f)
        assert rec["ok"], rec.get("error")
        assert rec["chips"] == (256 if mesh == "single" else 512)
        assert rec["cost"]["flops"] > 0
        assert rec["memory"]["argument_bytes"] > 0
    # roofline analysis over the fresh records
    from repro.launch.roofline import analyze_record

    a = analyze_record(rec)
    assert a["dominant"] in ("compute", "memory", "collective")
    assert 0 < a["useful_ratio"] < 10


def test_overlapped_collective_matmul():
    """Ring AG-matmul / RS-matmul == gathered reference, grads exact."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.training.collective_matmul import make_overlapped_tp_matmuls
        mesh = jax.make_mesh((4,), ("model",))
        ag, rs = make_overlapped_tp_matmuls(mesh)
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 24)) * 0.1
        assert float(jnp.abs(ag(x, w) - x @ w).max()) < 1e-5
        assert float(jnp.abs(rs(x, w) - x @ w).max()) < 1e-5
        g = jax.grad(lambda x, w: jnp.sum(ag(x, w) ** 2))(x, w)
        g_ref = jax.grad(lambda x, w: jnp.sum((x @ w) ** 2))(x, w)
        assert float(jnp.abs(g - g_ref).max()) < 1e-5
        print("OK")
    """, devices=4)
    assert "OK" in out
