"""End-to-end autoregressive decode through the plan compiler.

The golden gate for the decoder lowering: greedy decode driven through the
paged pipeline (prefill plan -> per-token decode plan over gathered
KV-cache spans) must produce the exact token sequence of a naive jnp
``forward`` loop on the same params -- on the ``reference``, ``kernel``
and ``guarded`` backends alike.  A final test drives the same traffic
through ``AsyncPlanServer.submit_llm`` continuous batching and checks the
streamed tokens, zero sequence loss, and zero page leak.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.core.graph import compile_plan
from repro.core.graph.passes import optimize
from repro.models.transformer import forward, init_lm
from repro.models.transformer_graph import build_decoder_graph, decoder_cache_spec
from repro.serving import AsyncPlanServer, PagedKVCache

BACKENDS = ("reference", "kernel", "guarded")


@pytest.fixture(scope="module")
def lm():
    cfg = smoke_config("qwen2.5-3b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    graphs = {
        phase: optimize(build_decoder_graph(params, cfg, phase=phase))
        for phase in ("prefill", "decode")
    }
    return cfg, params, graphs


def _plans(graphs, backend):
    interpret = backend != "reference"
    return {
        phase: compile_plan(g, backend=backend, interpret=interpret)
        for phase, g in graphs.items()
    }


def _greedy_naive(params, cfg, prompt, steps):
    seq = [int(t) for t in prompt]
    for _ in range(steps):
        logits, _ = forward(params, cfg, jnp.asarray([seq], jnp.int32))
        seq.append(int(jnp.argmax(logits[0, -1])))
    return seq[len(prompt):]


def _greedy_plan(cfg, graphs, plans, prompt, steps):
    """The serving pipeline by hand: one prefill, then per-token decode
    over gathered cache spans."""
    spec = decoder_cache_spec(cfg)
    g, dh = spec["n_kv_heads"], spec["head_dim"]
    cache = PagedKVCache(num_pages=16, page_size=4, **spec)
    cache.allocate(0)
    n0 = len(prompt)
    outs = plans["prefill"](
        graphs["prefill"].params,
        jnp.asarray([prompt], jnp.int32),
        jnp.asarray([list(range(n0))], jnp.int32),
        jnp.asarray([n0], jnp.int32),
    )
    kvs = [np.asarray(o[0]).reshape(n0, g, dh) for o in outs[1:]]
    cache.append(0, np.stack(kvs[0::2], 1), np.stack(kvs[1::2], 1))
    got = [int(np.argmax(np.asarray(outs[0])[0, -1]))]
    for _ in range(steps - 1):
        n = cache.length(0)
        cache.ensure_capacity(0, n + 1)
        k_ctx, v_ctx, lens = cache.gather([0], min_tokens=n + 1)
        outs = plans["decode"](
            graphs["decode"].params,
            jnp.asarray([[got[-1]]], jnp.int32),
            jnp.asarray([[n]], jnp.int32),
            jnp.asarray(k_ctx), jnp.asarray(v_ctx), jnp.asarray(lens),
        )
        kvs = [np.asarray(o[0]).reshape(1, g, dh) for o in outs[1:]]
        cache.append(0, np.stack(kvs[0::2], 1), np.stack(kvs[1::2], 1))
        got.append(int(np.argmax(np.asarray(outs[0])[0, -1])))
    cache.release(0)
    cache.check_invariants()
    assert cache.free_pages == cache.num_pages
    return got


def test_decoder_graphs_fuse(lm):
    _, _, graphs = lm
    for phase in ("prefill", "decode"):
        cfg, params, _ = lm
        raw = build_decoder_graph(params, cfg, phase=phase)
        unfused = len(compile_plan(raw, backend="reference").steps)
        fused = len(compile_plan(graphs[phase], backend="reference").steps)
        assert fused < unfused, (phase, fused, unfused)


@pytest.mark.parametrize("backend", BACKENDS)
def test_prefill_parity(lm, backend):
    cfg, params, graphs = lm
    plans = _plans(graphs, backend)
    rng = np.random.default_rng(1)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (2, 9)), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(9, dtype=jnp.int32), (2, 9))
    want, _ = forward(params, cfg, tok)
    outs = plans["prefill"](
        graphs["prefill"].params, tok, pos, jnp.full((2,), 9, jnp.int32)
    )
    err = float(jnp.max(jnp.abs(outs[0][..., : cfg.vocab] - want)))
    assert err <= 1e-4, err


@pytest.mark.parametrize("backend", BACKENDS)
def test_greedy_decode_golden(lm, backend):
    cfg, params, graphs = lm
    prompt = [int(t) for t in np.random.default_rng(2).integers(0, cfg.vocab, 5)]
    want = _greedy_naive(params, cfg, prompt, 4)
    got = _greedy_plan(cfg, graphs, _plans(graphs, backend), prompt, 4)
    assert got == want, (backend, got, want)


def test_server_continuous_batching_greedy(lm):
    cfg, params, graphs = lm
    plans = _plans(graphs, "reference")
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (3, 7, 5, 9)]
    want = [_greedy_naive(params, cfg, [int(t) for t in p], 3) for p in prompts]

    cache = PagedKVCache(num_pages=24, page_size=4, **decoder_cache_spec(cfg))
    server = AsyncPlanServer()
    server.add_llm("lm", prefill=plans["prefill"], decode=plans["decode"],
                   cache=cache, max_batch=2)
    handles = [server.submit_llm("lm", p, max_new_tokens=3) for p in prompts]
    while any(not h.done() for h in handles):
        server.step()
    st = server.stats["per_llm"]["lm"]
    server.close()

    for h, w in zip(handles, want):
        assert h.exception() is None
        assert [int(t) for t in h.result(0)] == w
        assert list(h.tokens_so_far()) == w
    assert st["completed"] == len(prompts) and st["failed"] == 0
    assert st["decode_batches"] >= 1 and st["prefill_batches"] >= 2
    cache.check_invariants()
    assert cache.used_pages == 0  # every page back on the freelist


def test_server_eos_and_cache_pressure(lm):
    """EOS stops a sequence early; a pool too small for the whole batch
    still drains everything (admission waits for freed pages)."""
    cfg, params, graphs = lm
    plans = _plans(graphs, "reference")
    rng = np.random.default_rng(4)
    prompt = [int(t) for t in rng.integers(0, cfg.vocab, 5)]
    first = _greedy_naive(params, cfg, prompt, 1)[0]

    # pool sized so only ~one sequence fits at a time
    cache = PagedKVCache(num_pages=4, page_size=4, **decoder_cache_spec(cfg))
    server = AsyncPlanServer()
    server.add_llm("lm", prefill=plans["prefill"], decode=plans["decode"],
                   cache=cache, max_batch=4)
    eos = server.submit_llm("lm", prompt, max_new_tokens=8, eos_id=first)
    rest = [server.submit_llm("lm", rng.integers(0, cfg.vocab, 6),
                              max_new_tokens=2) for _ in range(3)]
    while any(not h.done() for h in [eos] + rest):
        server.step()
    server.close()
    assert [int(t) for t in eos.result(0)] == [first]  # stopped at EOS
    assert all(h.exception() is None and len(h.result(0)) == 2 for h in rest)
    cache.check_invariants()
    assert cache.used_pages == 0

    # a prompt that can never fit is rejected up front, not deadlocked
    with pytest.raises(ValueError):
        AsyncPlanServer_ = AsyncPlanServer()
        AsyncPlanServer_.add_llm(
            "lm", prefill=plans["prefill"], decode=plans["decode"],
            cache=PagedKVCache(num_pages=2, page_size=2,
                               **decoder_cache_spec(cfg)))
        AsyncPlanServer_.submit_llm("lm", list(range(40)))
