"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles (ref.py).

All kernels run in interpret mode on CPU (the container has no TPU); the
BlockSpec tiling paths are identical to the hardware path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - fallback keeps collection alive
    from _hypothesis_fallback import given, settings, st

from repro.core.pruning import Block, Column, project
from repro.core.sparse import ColumnCompact, PBCSR, block_mask, plan_reorder, apply_column_perm
from repro.kernels import bsr_matmul, col_matmul, ffn_gateup, matmul, ref

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32) * 0.1
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# dense matmul + fused epilogue                                                #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,k,n", [(128, 128, 128), (256, 384, 512), (100, 200, 300), (1, 128, 128)]
)
@pytest.mark.parametrize("activation", [None, "relu", "gelu"])
def test_dense_matmul_sweep(dtype, m, k, n, activation):
    x = _rand(KEY, (m, k), dtype)
    w = _rand(jax.random.PRNGKey(1), (k, n), dtype)
    b = _rand(jax.random.PRNGKey(2), (n,), dtype)
    got = matmul(x, w, b, activation=activation)
    want = ref.matmul_ref(x, w, b, activation=activation)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


def test_dense_matmul_batched_leading_dims():
    x = _rand(KEY, (2, 3, 100), jnp.float32)
    w = _rand(jax.random.PRNGKey(1), (100, 60), jnp.float32)
    got = matmul(x, w)
    want = ref.matmul_ref(x.reshape(-1, 100), w).reshape(2, 3, 60)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- #
# block-sparse matmul                                                          #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sparsity", [0.3, 0.5, 0.75])
@pytest.mark.parametrize("bm,bn", [(128, 128), (64, 128)])
def test_bsr_matmul_sweep(dtype, sparsity, bm, bn):
    k, n, m = 512, 768, 128
    w = _rand(jax.random.PRNGKey(1), (k, n), jnp.float32)
    wp, mask = project(w, Block(sparsity, bm=bm, bn=bn))
    fmt = PBCSR.from_dense(wp.astype(dtype), mask, bm, bn)
    x = _rand(KEY, (m, k), dtype)
    got = bsr_matmul(x, fmt.values, fmt.block_rows)
    want = ref.matmul_ref(x, wp.astype(dtype))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


def test_bsr_matmul_with_bias_activation():
    k, n = 256, 384
    w = _rand(jax.random.PRNGKey(1), (k, n), jnp.float32)
    wp, mask = project(w, Block(0.5, bm=128, bn=128))
    fmt = PBCSR.from_dense(wp, mask, 128, 128)
    x = _rand(KEY, (64, k), jnp.float32)
    b = _rand(jax.random.PRNGKey(2), (n,), jnp.float32)
    got = bsr_matmul(x, fmt.values, fmt.block_rows, b, activation="silu")
    want = ref.matmul_ref(x, wp, b, activation="silu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_bsr_matmul_banded_matches_reordered_dense():
    """Unbalanced mask -> reorder plan -> banded execution == dense."""
    k, n = 512, 768
    w = _rand(jax.random.PRNGKey(3), (k, n), jnp.float32)
    wp, mask = project(w, Block(0.6, bm=128, bn=128, balanced=False))
    bm_ = np.asarray(block_mask(mask, 128, 128))
    plan = plan_reorder(bm_, max_bands=3)
    w_perm = apply_column_perm(wp, plan.order, 128)
    m_perm = apply_column_perm(mask, plan.order, 128)
    fmt = PBCSR.from_dense(w_perm, m_perm, 128, 128)
    x = _rand(KEY, (64, k), jnp.float32)
    bands = [(b.start, b.stop, b.count) for b in plan.bands]
    got = bsr_matmul(x, fmt.values, fmt.block_rows, bands=bands)
    want = ref.matmul_ref(x, w_perm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_bsr_padding_blocks_are_exact_zero_contributions():
    """-1 padded slots must add exactly nothing (not read garbage)."""
    k, n, bmk = 256, 256, 128
    vals = jnp.zeros((2, 2, bmk, bmk), jnp.float32)
    vals = vals.at[0, 0].set(jnp.eye(bmk))
    rows = jnp.array([[0, -1], [1, -1]], jnp.int32)
    x = _rand(KEY, (128, k), jnp.float32)
    got = bsr_matmul(x, vals, rows)
    want = jnp.concatenate([x[:, :128], jnp.zeros((128, 128))], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(st.integers(0, 3), st.sampled_from([0.4, 0.6]))
@settings(max_examples=6, deadline=None)
def test_bsr_matmul_property(seed, sparsity):
    k, n = 256, 256
    w = _rand(jax.random.PRNGKey(seed), (k, n), jnp.float32)
    wp, mask = project(w, Block(sparsity, bm=64, bn=64, balanced=False))
    fmt = PBCSR.from_dense(wp, mask, 64, 64)
    x = _rand(jax.random.PRNGKey(seed + 100), (128, k), jnp.float32)
    got = bsr_matmul(x, fmt.values, fmt.block_rows)
    want = ref.bsr_matmul_ref(x, fmt.values, fmt.block_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- #
# column-pruned matmul + fused FFN                                             #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_col_matmul(dtype):
    k, n = 512, 256
    w = _rand(jax.random.PRNGKey(1), (k, n), jnp.float32)
    wp, mask = project(w, Column(0.5))
    cc = ColumnCompact.from_dense(wp.astype(dtype), mask)
    x = _rand(KEY, (32, k), dtype)
    got = col_matmul(x, cc.values, cc.kept)
    want = ref.matmul_ref(x, wp.astype(dtype))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("activation", ["silu", "gelu"])
def test_ffn_gateup(dtype, activation):
    d, f = 300, 250
    x = _rand(KEY, (2, 17, d), dtype)
    wg = _rand(jax.random.PRNGKey(1), (d, f), dtype)
    wu = _rand(jax.random.PRNGKey(2), (d, f), dtype)
    got = ffn_gateup(x, wg, wu, activation=activation)
    want = ref.ffn_gateup_ref(x.reshape(-1, d), wg, wu, activation=activation).reshape(2, 17, f)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


def test_bsr_flops_scale_with_density():
    """Packed sizes scale with density -- the compute-scales-with-density
    contract (values tensor is the only O(big) buffer)."""
    k, n = 512, 512
    w = _rand(KEY, (k, n), jnp.float32)
    sizes = {}
    for sp in (0.25, 0.5, 0.75):
        wp, mask = project(w, Block(sp, bm=128, bn=128))
        fmt = PBCSR.from_dense(wp, mask, 128, 128)
        sizes[sp] = int(fmt.values.size)
    assert sizes[0.75] < sizes[0.5] < sizes[0.25]


# --------------------------------------------------------------------------- #
# flash attention                                                              #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,skv,d", [(128, 128, 64), (256, 256, 64), (200, 200, 32)])
def test_flash_attention_causal_sweep(dtype, sq, skv, d):
    from repro.kernels import attention

    q = _rand(KEY, (2, 2, sq, d), dtype) * 3
    k = _rand(jax.random.PRNGKey(1), (2, 2, skv, d), dtype) * 3
    v = _rand(jax.random.PRNGKey(2), (2, 2, skv, d), dtype) * 3
    got = attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_flash_attention_noncausal_and_scale():
    from repro.kernels import attention

    q = _rand(KEY, (1, 2, 128, 64), jnp.float32) * 3
    k = _rand(jax.random.PRNGKey(1), (1, 2, 256, 64), jnp.float32) * 3
    v = _rand(jax.random.PRNGKey(2), (1, 2, 256, 64), jnp.float32) * 3
    got = attention(q, k, v, causal=False, scale=0.5)
    want = ref.flash_attention_ref(q, k, v, causal=False, scale=0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_model_sdpa():
    """The Pallas kernel and the model-side jnp chunked sdpa agree."""
    from repro.kernels import attention
    from repro.models.attention import sdpa

    b, h, s, d = 1, 2, 256, 32
    q = _rand(KEY, (b, h, s, d), jnp.float32) * 3
    k = _rand(jax.random.PRNGKey(1), (b, h, s, d), jnp.float32) * 3
    v = _rand(jax.random.PRNGKey(2), (b, h, s, d), jnp.float32) * 3
    got = attention(q, k, v, causal=True)
    pos = jnp.arange(s)
    want = sdpa(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        pos, pos, causal=True, impl="chunked", chunk=64,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# PR 6: hand-pipelined double-buffered K streaming (pipeline >= 2)             #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("depth", [2, 3])
def test_dense_matmul_pipelined_matches_grid_k(depth):
    """The depth-N DMA ring streams x/w K-slabs HBM->VMEM by hand; the
    accumulated result matches the compiler-scheduled grid-K path and the
    oracle, epilogue included."""
    x = _rand(KEY, (256, 384), jnp.float32)
    w = _rand(jax.random.PRNGKey(1), (384, 256), jnp.float32)
    b = _rand(jax.random.PRNGKey(2), (256,), jnp.float32)
    side = _rand(jax.random.PRNGKey(3), (256, 256), jnp.float32)
    from repro.kernels import ops as kops

    got = kops.matmul(
        x, w, b, activation="relu", epilogue=(("add", 0),),
        epilogue_sides=(side,), block_m=128, block_n=128, block_k=128,
        pipeline=depth,
    )
    want = ref.apply_steps_ref(
        ref.matmul_ref(x, w, b, activation="relu"), (("add", 0),), [side]
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(jnp.float32))


def test_dense_matmul_pipelined_ragged_k_pads_exactly():
    """K not divisible by block_k: the wrapper zero-pads; padded slabs
    contribute exact zeros through the DMA ring."""
    from repro.kernels import ops as kops

    x = _rand(KEY, (64, 300), jnp.float32)
    w = _rand(jax.random.PRNGKey(1), (300, 96), jnp.float32)
    got = kops.matmul(x, w, pipeline=2)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul_ref(x, w)), **_tol(jnp.float32)
    )


@pytest.mark.parametrize("scheme", ["w8", "w8a8"])
def test_quant_matmul_pipelined_matches_oracle(scheme):
    """w8a8 accumulates int8 x int8 -> int32 across the ring (bit-exact with
    grid-K); w8 dequantizes each streamed slab in VMEM."""
    from repro.kernels import ops as kops
    from repro.quant import QTensor

    xf = _rand(KEY, (128, 384), jnp.float32)
    wf = _rand(jax.random.PRNGKey(1), (384, 128), jnp.float32)
    qt = QTensor.from_float(wf, axis=1)
    xs = float(jnp.max(jnp.abs(xf))) / 127.0 if scheme == "w8a8" else None
    got = kops.qmatmul(xf, qt.values, qt.scale, x_scale=xs, pipeline=2)
    want = ref.qmatmul_ref(xf, qt.values, qt.scale, x_scale=xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(jnp.float32))
    if scheme == "w8a8":  # integer accumulation: grid-K and ring bit-match
        base = kops.qmatmul(xf, qt.values, qt.scale, x_scale=xs, pipeline=1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
