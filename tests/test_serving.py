"""PlanServer v2 (`serving/scheduler.py`): the async continuous-batching
engine.  Deterministic coverage drives the synchronous :meth:`step` tick
with an injected clock; the threaded tests exercise the background
scheduler the way production would.  Edge cases from the issue checklist:
deadline with an empty queue, close() under in-flight async requests,
backpressure rejection/shedding, multi-plan fairness under skewed traffic,
and drain_completed on the async path."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import compile_plan, optimize
from repro.models.cnn import APPS, app_masks
from repro.serving import AsyncPlanServer, QueueFullError

KEY = jax.random.PRNGKey(0)
FRAME = (3, 8, 8)  # super_resolution single-frame shape at base=8


def _plan(app="super_resolution"):
    g = APPS[app](KEY, base=8)
    masks, structures = app_masks(g, app, sparsity=0.5)
    go = optimize(g, masks, structures)
    return go, compile_plan(go, backend="reference")


@pytest.fixture(scope="module")
def sr():
    return _plan()


@pytest.fixture(scope="module")
def coloring():
    return _plan("coloring")


def _server(sr, clock=None, **kw):
    go, plan = sr
    server = AsyncPlanServer(clock=clock or (lambda: 0.0), **kw)
    server.add_plan("sr", plan, go.params, batch_size=4)
    return server


def _frames(n, shape=FRAME):
    return [jax.random.normal(jax.random.PRNGKey(i), shape) for i in range(n)]


# --------------------------------------------------------------------------- #
# deterministic scheduling (synchronous step, injected clock)                  #
# --------------------------------------------------------------------------- #


def test_submit_returns_pending_handle_and_full_batch_executes(sr):
    go, plan = sr
    server = _server(sr)
    frames = _frames(4)
    handles = [server.submit("sr", f) for f in frames]
    assert all(not h.done() for h in handles)  # admission != execution
    assert server.pending("sr") == 4
    assert server.step() == 1  # full batch: releases without any deadline
    assert server.pending() == 0 and all(h.done() for h in handles)
    want = plan(go.params, jnp.stack(frames))
    for i, h in enumerate(handles):
        np.testing.assert_allclose(
            np.asarray(h.result(0)), np.asarray(want)[i], rtol=1e-5, atol=1e-5
        )
    assert server.stats["padded_frames"] == 0
    server.close()


def test_partial_batch_waits_until_flush_after(sr):
    now = [0.0]
    server = _server(sr, clock=lambda: now[0], flush_after=1.0)
    h = server.submit("sr", _frames(1)[0])
    assert server.step() == 0 and not h.done()  # batch fill beats padding
    now[0] = 0.99
    assert server.step() == 0
    now[0] = 1.0  # oldest request has now waited out the release deadline
    assert server.step() == 1 and h.done()
    assert server.stats["deadline_flushes"] == 1
    assert server.stats["padded_frames"] == 3
    server.close()


def test_deadline_with_empty_queue_is_noop(sr):
    """An expired engine deadline with nothing queued must not flush, count,
    or crash -- on the sync path and on a ticking scheduler thread."""
    now = [100.0]  # far past any deadline from t=0
    server = _server(sr, clock=lambda: now[0], flush_after=0.5)
    assert server.step() == 0
    assert server.step(force=True) == 0
    assert server.stats["deadline_flushes"] == 0
    assert server.stats["batches"] == 0
    server.start()  # idle ticks over an empty queue
    server.close()
    assert server.stats["batches"] == 0


def test_per_request_deadline_releases_partial_batch_and_counts_miss(sr):
    now = [0.0]
    server = _server(sr, clock=lambda: now[0])  # NO engine-level flush_after
    h_slack = server.submit("sr", _frames(1)[0])  # best-effort: never releases
    assert server.step() == 0
    h = server.submit("sr", _frames(2)[1], deadline=0.5)
    assert server.step() == 0  # deadline not yet reached
    now[0] = 0.6  # past the request's budget: release NOW, already late
    assert server.step() == 1
    assert h.done() and h_slack.done()  # same macro-batch
    assert h.deadline_missed and h.latency == pytest.approx(0.6)
    assert not h_slack.deadline_missed  # best-effort requests never miss
    assert server.stats["deadline_misses"] == 1
    assert server.stats["deadline_flushes"] == 1
    server.close()


def test_priority_classes_jump_the_queue(sr):
    go, plan = sr
    server = _server(sr)
    lo = [server.submit("sr", f, priority=0) for f in _frames(4)]
    hi = [server.submit("sr", f, priority=1) for f in _frames(6)[4:]]
    assert server.step() == 1  # one full batch released
    # both high-priority requests ran in the first batch, with the two
    # oldest low-priority requests filling the remaining slots
    assert all(h.done() for h in hi)
    assert [h.done() for h in lo] == [True, True, False, False]
    assert server.step(force=True) == 1  # drain the rest
    assert all(h.done() for h in lo)
    server.close()


def test_submit_validates_plan_name_and_arity(sr):
    server = _server(sr)
    with pytest.raises(KeyError, match="unknown plan"):
        server.submit("nope", _frames(1)[0])
    with pytest.raises(TypeError, match="inputs per frame"):
        server.submit("sr", _frames(1)[0], _frames(1)[0])
    with pytest.raises(ValueError, match="already registered"):
        server.add_plan("sr", sr[1], sr[0].params, 4)
    server.close()


# --------------------------------------------------------------------------- #
# backpressure                                                                 #
# --------------------------------------------------------------------------- #


def test_backpressure_reject_policy(sr):
    server = _server(sr, max_queue=2, overload="reject")
    h0 = server.submit("sr", _frames(1)[0])
    server.submit("sr", _frames(2)[1])
    with pytest.raises(QueueFullError, match="queue full"):
        server.submit("sr", _frames(3)[2])
    assert server.stats["rejected"] == 1
    assert server.pending("sr") == 2  # the queue itself is untouched
    assert not h0.done()
    server.close()
    assert h0.done()  # close drained the queued ones


def test_backpressure_shed_policy_evicts_scheduled_last(sr):
    """The shed victim is whichever of queue + {incoming} would be
    scheduled LAST (lowest priority class, newest arrival): at equal
    priority the newcomer itself is turned away, only a strictly
    higher-priority submit evicts queued work, and higher-priority queued
    requests are untouchable."""
    server = _server(sr, max_queue=2, overload="shed")
    h_hi = server.submit("sr", _frames(1)[0], priority=1)
    h_a = server.submit("sr", _frames(2)[1], priority=0)
    with pytest.raises(QueueFullError, match="shed"):  # equal prio: newcomer loses
        server.submit("sr", _frames(3)[2], priority=0)
    assert not h_a.done()  # queued work untouched by the failed newcomer
    h_b = server.submit("sr", _frames(4)[3], priority=2)  # evicts h_a
    assert h_a.done() and not h_b.done()
    assert h_a._inputs is None  # eviction releases the frame arrays
    with pytest.raises(QueueFullError, match="shed"):
        h_a.result(0)
    assert server.stats["shed"] == 2 and server.stats["rejected"] == 0
    server.close()
    assert h_hi.done() and h_b.done()
    assert h_hi.exception() is None and h_b.exception() is None


def test_backpressure_shed_never_inverts_priority(sr):
    """A full queue of high-priority requests must turn a low-priority
    newcomer away rather than evict any of them."""
    server = _server(sr, max_queue=2, overload="shed")
    hi = [server.submit("sr", f, priority=5) for f in _frames(2)]
    with pytest.raises(QueueFullError, match="shed"):
        server.submit("sr", _frames(3)[2], priority=0)
    assert not any(h.done() for h in hi)  # nothing evicted
    assert server.stats["shed"] == 1
    server.close()
    assert all(h.exception() is None for h in hi)


def test_due_deadline_wins_batch_membership_over_priority(sr):
    """Deadline urgency outranks priority class for batch MEMBERSHIP: under
    sustained full-batch pressure from a higher priority class, a due
    low-priority request joins the released batch instead of starving
    while its deadline keeps triggering releases that exclude it."""
    now = [0.0]
    server = _server(sr, clock=lambda: now[0])
    h_low = server.submit("sr", _frames(1)[0], priority=0, deadline=0.5)
    hi = [server.submit("sr", f, priority=1) for f in _frames(7)[1:]]
    now[0] = 0.6  # h_low is due; the queue is also over batch_size
    assert server.step() == 1
    assert h_low.done()  # in the batch, displacing one high-priority slot
    assert sum(h.done() for h in hi) == 3
    server.close()


# --------------------------------------------------------------------------- #
# multi-plan routing + fairness                                                #
# --------------------------------------------------------------------------- #


def test_multi_plan_routing_parity(sr, coloring):
    go_s, plan_s = sr
    go_c, plan_c = coloring
    server = AsyncPlanServer(clock=lambda: 0.0)
    server.add_plan("sr", plan_s, go_s.params, batch_size=2)
    server.add_plan("coloring", plan_c, go_c.params, batch_size=2)
    assert server.plans == ("sr", "coloring")
    fs = _frames(2)
    fc = _frames(2, (1, 16, 16))
    hs = [server.submit("sr", f) for f in fs]
    hc = [server.submit("coloring", f) for f in fc]
    assert server.step() == 2  # both full queues release in one tick
    want_s = plan_s(go_s.params, jnp.stack(fs))
    want_c = plan_c(go_c.params, jnp.stack(fc))
    for i, h in enumerate(hs):
        np.testing.assert_allclose(
            np.asarray(h.result(0)), np.asarray(want_s)[i], rtol=1e-5, atol=1e-5
        )
    for i, h in enumerate(hc):
        np.testing.assert_allclose(
            np.asarray(h.result(0)), np.asarray(want_c)[i], rtol=1e-5, atol=1e-5
        )
    per_plan = server.stats["per_plan"]
    assert per_plan["sr"]["completed"] == 2
    assert per_plan["coloring"]["completed"] == 2
    server.close()


def test_fairness_under_skewed_traffic(sr, coloring):
    """A flood on one plan must not starve the other: round-robin gives the
    light plan a batch slot every tick, so its lone full batch completes
    within the first two ticks regardless of the heavy backlog."""
    go_s, plan_s = sr
    go_c, plan_c = coloring
    server = AsyncPlanServer(clock=lambda: 0.0)
    server.add_plan("heavy", plan_s, go_s.params, batch_size=2)
    server.add_plan("light", plan_c, go_c.params, batch_size=2)
    heavy = [server.submit("heavy", f) for f in _frames(20)]
    light = [server.submit("light", f) for f in _frames(2, (1, 16, 16))]
    ticks = 0
    while not all(h.done() for h in light):
        assert server.step() >= 1
        ticks += 1
    assert ticks <= 2  # not behind the 10-batch heavy backlog
    assert sum(h.done() for h in heavy) <= 2 * server._plans["heavy"].batched.batch_size
    server.close()
    assert all(h.done() for h in heavy)


# --------------------------------------------------------------------------- #
# drain_completed on the async path                                            #
# --------------------------------------------------------------------------- #


def test_drain_completed_hands_over_in_completion_order_once(sr):
    server = _server(sr)
    assert server.drain_completed() == []  # nothing completed yet
    h1 = [server.submit("sr", f) for f in _frames(4)]
    server.step()
    h2 = [server.submit("sr", f) for f in _frames(4)]
    server.step()
    done = server.drain_completed()
    assert done == h1 + h2  # completion order, batch by batch
    assert server.drain_completed() == []  # drained exactly once
    server.submit("sr", _frames(1)[0])
    server.close()
    assert len(server.drain_completed()) == 1  # close-drained request lands too
    server.close()  # idempotent


def test_drain_completed_with_background_thread(sr):
    server = _server(sr, clock=time.monotonic, flush_after=0.005, tick_interval=0.001)
    server.start()
    handles = [server.submit("sr", f) for f in _frames(6)]
    for h in handles:
        h.result(30.0)
    drained = server.drain_completed()
    assert sorted(h.rid for h in drained) == [h.rid for h in handles]
    server.close()
    assert server.drain_completed() == []


def test_bad_frame_fails_at_submit_not_its_batch(sr):
    """A wrong-shape/dtype frame is rejected by submit() itself
    (FrameSpecError against the latched input spec), so it can never poison
    the macro-batch it would have joined: the good requests around it
    complete normally and the rejection is counted."""
    from repro.serving import FrameSpecError

    server = _server(sr)
    h_ok = server.submit("sr", _frames(1)[0])  # latches the input spec
    with pytest.raises(FrameSpecError):
        server.submit("sr", jnp.zeros((3, 4, 4)))  # wrong spatial dims
    with pytest.raises(FrameSpecError):
        server.submit("sr", jnp.zeros(FRAME, jnp.int32))  # wrong dtype
    assert server.step(force=True) == 1
    assert h_ok.exception() is None and h_ok.result(0).shape
    assert server.stats["per_plan"]["sr"]["bad_frames"] == 2
    assert server.stats["per_plan"]["sr"]["submitted"] == 1
    server.close()


def test_explicit_input_spec_rejects_first_bad_frame(sr):
    """With input_spec given at add_plan, even the FIRST frame is validated
    (nothing to latch), closing the malformed-first-request hole."""
    from repro.serving import FrameSpecError

    go, plan = sr
    server = AsyncPlanServer(clock=lambda: 0.0)
    server.add_plan(
        "sr", plan, go.params, batch_size=4,
        input_spec=[(FRAME, jnp.float32)],
    )
    with pytest.raises(FrameSpecError):
        server.submit("sr", jnp.zeros((3, 4, 4)))
    h = server.submit("sr", _frames(1)[0])
    server.step(force=True)
    assert h.result(0).shape
    server.close()


# --------------------------------------------------------------------------- #
# close / teardown                                                             #
# --------------------------------------------------------------------------- #


def test_close_under_inflight_async_requests(sr):
    """close() while the scheduler thread is mid-flight: every accepted
    request still resolves (queued ones force-drain, in-flight batches
    complete), and the server refuses new work."""
    server = _server(sr, clock=time.monotonic, flush_after=10.0, tick_interval=0.001)
    server.start()
    handles = [server.submit("sr", f) for f in _frames(11)]  # 2 full + partial
    drained = server.close()  # immediately: some batches likely in flight
    assert not server.running and server.closed
    assert all(h.done() for h in handles)  # nothing lost, nothing dropped
    assert all(h.exception() is None for h in handles)
    assert drained >= 0  # whatever the thread didn't get to, close drained
    assert server.stats["completed"] == 11
    with pytest.raises(RuntimeError, match="closed"):
        server.submit("sr", _frames(1)[0])
    with pytest.raises(RuntimeError, match="closed"):
        server.start()
    with pytest.raises(RuntimeError, match="closed"):
        server.add_plan("sr2", sr[1], sr[0].params, 4)


def test_context_manager_drains_on_exit(sr):
    with _server(sr) as server:
        h = server.submit("sr", _frames(1)[0])
    assert server.closed and h.done()


def test_result_timeout_and_exception_surfaces(sr):
    server = _server(sr)
    h = server.submit("sr", _frames(1)[0])
    with pytest.raises(TimeoutError, match="not done"):
        h.result(0)
    assert h.exception() is None  # not done yet -> no exception view
    assert h.latency is None
    server.close()
    assert h.latency == 0.0  # injected clock never advanced


# --------------------------------------------------------------------------- #
# BatchedPlan: chunk-execute entry point + thread-safe stats                   #
# --------------------------------------------------------------------------- #


def test_run_chunk_bounds_and_padding(sr):
    go, plan = sr
    bp = plan.batched(4)
    frames = _frames(3)
    out = bp.run_chunk(go.params, jnp.stack(frames))
    assert out.shape[0] == 3  # padding sliced off
    want = plan(go.params, jnp.stack(frames))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert bp.total_stats == {"frames": 3, "batches": 1, "padded_frames": 1}
    with pytest.raises(ValueError, match="at most batch_size"):
        bp.run_chunk(go.params, jnp.zeros((5, 3, 8, 8)))
    with pytest.raises(ValueError, match="empty macro-batch"):
        bp.run_chunk(go.params, jnp.zeros((0, 3, 8, 8)))


def test_batched_plan_total_stats_accumulate_across_threads(sr):
    """total_stats is the scheduler's ledger: hammer run_chunk from several
    threads and the counters must come out exact (lock-protected)."""
    go, plan = sr
    bp = plan.batched(2)
    x = jnp.stack(_frames(1))
    jax.block_until_ready(bp.run_chunk(go.params, x))  # compile once up front
    errs = []

    def worker():
        try:
            for _ in range(5):
                bp.run_chunk(go.params, x)
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert bp.total_stats == {"frames": 21, "batches": 21, "padded_frames": 21}


def test_batched_plan_call_still_reports_last_stats(sr):
    """v1 consumers (PlanServer.flush) read last_stats per call; the chunked
    rewrite must preserve that contract alongside the cumulative ledger."""
    go, plan = sr
    bp = plan.batched(2)
    out = bp(go.params, jnp.stack(_frames(5)))
    assert out.shape[0] == 5
    assert bp.last_stats == {"frames": 5, "batches": 3, "padded_frames": 1}
    assert bp.total_stats == {"frames": 5, "batches": 3, "padded_frames": 1}
