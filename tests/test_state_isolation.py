"""Global-state isolation: no test may observe another's mutations of the
process-level kernel/obs state (the metrics registry -- which now hosts
the conv fallback and guard demotion counters -- the tracing switch, and
the TuningCache singleton).  The autouse fixture in conftest.py enforces
this; the tests here prove ORDER INDEPENDENCE by running two state-mutating
"tests" in both orders through the same snapshot/restore machinery and
asserting each sees pristine state regardless of which ran first."""

import jax
import jax.numpy as jnp
import pytest
from conftest import restore_global_state, snapshot_global_state

from repro.kernels import ops as kops


def _mutate_fallback_counters():
    """Mutator A: a grouped conv is a documented lax.conv fallback -- running
    one bumps the process-level counter."""
    x = jnp.ones((1, 4, 6, 6))
    w = jnp.ones((4, 2, 3, 3))
    kops.conv2d(x, w, groups=2, interpret=True)
    assert kops.conv_fallback_counts().get("groups", 0) >= 1


def _mutate_tuning_cache():
    """Mutator B: poke a winner + flip the enabled flag on the singleton."""
    cache = kops.tuning_cache()
    cache.entries["matmul|1x1x1|float32|dense|interpret"] = kops.TuneEntry(
        (8, 128, 128), "swept", 0.1
    )
    cache.enabled = not cache.enabled
    cache.sweeps += 7


def _mutate_guard_state():
    """Mutator C: bump the guarded-executor demotion counters (now a
    registry family) and leave a FaultPlan installed (deliberately not
    uninstalled -- restore must force-uninstall it so patched kernel entry
    points never leak)."""
    from repro.obs import metrics
    from repro.robustness import FaultPlan, FaultRule, active_fault_plan

    metrics.registry().counter(
        "guard_demotions_total", op="linear", scheme="f32", reason="exception"
    ).inc(3)
    FaultPlan([FaultRule("matmul", "raise")]).install()
    assert active_fault_plan() is not None


def _mutate_obs_state():
    """Mutator D: dirty the metrics registry with fresh families AND flip
    the process tracing switch on (buffer + enabled flag) -- restore must
    drop the families and disarm tracing."""
    from repro.obs import metrics, trace

    metrics.registry().counter("isolation_probe_total", case="d").inc(2)
    metrics.registry().histogram("isolation_probe_ms", case="d").observe(1.5)
    trace.start_tracing()
    trace.instant("probe", cat="test")
    assert trace.enabled()


def _assert_pristine(baseline):
    assert snapshot_global_state() == baseline


@pytest.mark.parametrize(
    "order",
    ["ab", "ba", "ac", "ca", "bc", "cb", "ad", "da", "bd", "db", "cd", "dc"],
)
def test_mutators_are_isolated_in_both_orders(order):
    """Run the mutator pairs in both orders, each wrapped in the fixture's
    snapshot/restore; the state observed before and after every mutator must
    equal the pristine baseline, independent of order."""
    baseline = snapshot_global_state()
    mutators = {
        "a": _mutate_fallback_counters,
        "b": _mutate_tuning_cache,
        "c": _mutate_guard_state,
        "d": _mutate_obs_state,
    }
    for key in order:
        _assert_pristine(baseline)  # previous mutator's damage fully undone
        snap = snapshot_global_state()
        try:
            mutators[key]()
            assert snapshot_global_state() != baseline  # it really mutated
        finally:
            restore_global_state(snap)
    _assert_pristine(baseline)


def test_fixture_restores_fallback_counters():
    """The autouse fixture itself: mutate freely here; the companion test
    below (collected AFTER this one in file order, and possibly before it
    under -n auto) must never see the mutation either way."""
    _mutate_fallback_counters()
    assert kops.conv_fallback_counts()


def test_fixture_left_no_fallback_residue():
    assert kops.conv_fallback_counts().get("groups", 0) == 0


def test_fixture_restores_tuning_cache():
    cache = kops.tuning_cache()
    before = dict(cache.entries)
    _mutate_tuning_cache()
    assert cache.entries != before


def test_fixture_left_no_tuning_residue():
    assert "matmul|1x1x1|float32|dense|interpret" not in kops.tuning_cache().entries


def test_fixture_restores_guard_state():
    from repro.core.graph import guard_fallback_counts
    from repro.robustness import active_fault_plan

    _mutate_guard_state()
    assert guard_fallback_counts().get("linear/f32/exception", 0) >= 3
    assert active_fault_plan() is not None


def test_fixture_restores_obs_state():
    from repro.obs import metrics, trace

    _mutate_obs_state()
    assert "isolation_probe_total" in metrics.registry().names()
    assert trace.enabled()


def test_fixture_left_no_obs_residue():
    from repro.obs import metrics, trace

    assert "isolation_probe_total" not in metrics.registry().names()
    assert not trace.enabled()
    assert trace.current_buffer() is None


def test_fixture_left_no_guard_residue():
    from repro.core.graph import guard_fallback_counts
    from repro.kernels import ops as kops_mod
    from repro.robustness import active_fault_plan

    assert guard_fallback_counts().get("linear/f32/exception", 0) == 0
    assert active_fault_plan() is None
    # the entry point itself is pristine (no faulty_ wrapper leaked)
    assert not getattr(kops_mod.matmul, "__name__", "").startswith("faulty_")
