"""Per-architecture smoke tests (deliverable f) + decode/prefill consistency.

Every assigned arch instantiates a REDUCED same-family config, runs one
forward/train step on CPU, and asserts output shapes + finite values.  The
full configs are exercised only by the dry-run (ShapeDtypeStruct)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config, shape_cells
from repro.models import get_model
import repro.models.transformer as lm
import repro.models.encdec as encdec

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b, s, key=KEY):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.vision_tokens:
        batch["patch_embeds"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one train step on the reduced config: shapes + no NaNs."""
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = model.init(KEY)
    b, s = 2, 32
    batch = _batch_for(cfg, b, s)
    logits = model.forward(params, {k: v for k, v in batch.items() if k != "labels"})
    assert logits.shape == (b, s, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss)
    # gradients exist, are finite, and a small step keeps the loss finite
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32)))) for x in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0.0
    p2 = jax.tree.map(lambda a, b_: a - 1e-3 * b_.astype(a.dtype), params, g)
    loss2, _ = model.loss(p2, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_full_config_matches_assignment(arch):
    """Exact values from the assignment table (guards against config drift)."""
    cfg = get_config(arch)
    table = {
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, None, 102400),
        "deepseek-v2-236b": (60, 5120, 128, 128, None, 102400),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }[arch]
    L, d, h, kv, dff, v = table
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab == v
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    if dff is not None:
        assert cfg.d_ff == dff
    if arch.startswith("deepseek"):
        assert cfg.kv_lora_rank == 512 and cfg.moe.top_k == 6
        assert cfg.moe.d_expert == (1408 if "lite" in arch else 1536)
        assert cfg.moe.n_shared == 2
    if arch == "mamba2-1.3b":
        assert cfg.ssm.d_state == 128
    if arch == "recurrentgemma-9b":
        assert cfg.recurrent.pattern == ("rec", "rec", "attn")
    if arch == "qwen3-14b":
        assert cfg.qk_norm
    if arch == "qwen2.5-3b":
        assert cfg.qkv_bias


@pytest.mark.parametrize(
    "arch", ["qwen2.5-3b", "qwen3-14b", "granite-3-2b", "mamba2-1.3b",
             "recurrentgemma-9b", "paligemma-3b"]
)
def test_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = model.init(KEY)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    kw = {}
    if cfg.vision_tokens:
        kw["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.vision_tokens, cfg.d_model), jnp.float32
        )
    full, _ = lm.forward(params, cfg, toks, **kw)
    caches = model.init_cache(b, s + (cfg.vision_tokens or 0))
    if cfg.vision_tokens:
        # VLM decode follows a prefill that consumed the image prefix
        _, caches = lm.prefill(params, cfg, toks[:, :1], s + cfg.vision_tokens, **kw)
        outs = []
        for t in range(1, s):
            lg, caches = lm.decode_step(params, cfg, toks[:, t : t + 1], caches)
            outs.append(lg[:, 0])
        got = jnp.stack(outs, axis=1)
        want = full[:, 1:]
    else:
        outs = []
        for t in range(s):
            lg, caches = lm.decode_step(params, cfg, toks[:, t : t + 1], caches)
            outs.append(lg[:, 0])
        got = jnp.stack(outs, axis=1)
        want = full
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=1e-3, atol=2e-4
    )


def test_moe_decode_matches_forward_with_headroom():
    """MoE equivalence requires no capacity drops (known GShard semantics)."""
    cfg = smoke_config("deepseek-v2-lite-16b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = get_model(cfg)
    params = model.init(KEY)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    full, _ = lm.forward(params, cfg, toks)
    caches = model.init_cache(b, s)
    outs = []
    for t in range(s):
        lg, caches = lm.decode_step(params, cfg, toks[:, t : t + 1], caches)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(full), rtol=1e-3, atol=2e-4
    )


def test_prefill_then_decode_matches_forward():
    cfg = smoke_config("qwen3-14b")
    model = get_model(cfg)
    params = model.init(KEY)
    b, s, extra = 2, 20, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + extra), 0, cfg.vocab)
    full, _ = lm.forward(params, cfg, toks)
    lg, caches = lm.prefill(params, cfg, toks[:, :s], s + extra)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, :s]), rtol=1e-3, atol=2e-4)
    for t in range(s, s + extra):
        lg_t, caches = lm.decode_step(params, cfg, toks[:, t : t + 1], caches)
        np.testing.assert_allclose(
            np.asarray(lg_t[:, 0]), np.asarray(full[:, t]), rtol=1e-3, atol=2e-4
        )


def test_sliding_window_ring_buffer_long_decode():
    """Hybrid arch decodes past the window: ring buffer must stay exact."""
    cfg = smoke_config("recurrentgemma-9b")  # window=32
    model = get_model(cfg)
    params = model.init(KEY)
    b, s = 1, 48  # exceeds the 32-token window
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    full, _ = lm.forward(params, cfg, toks)
    caches = model.init_cache(b, s)
    outs = []
    for t in range(s):
        lg, caches = lm.decode_step(params, cfg, toks[:, t : t + 1], caches)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(full), rtol=1e-3, atol=3e-4
    )


def test_encdec_decode_matches_teacher_forcing():
    cfg = smoke_config("whisper-small")
    model = get_model(cfg)
    params = model.init(KEY)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    frames = jax.random.normal(jax.random.PRNGKey(2), (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    enc = encdec.encode(params, cfg, frames)
    full = encdec.decode_train(params, cfg, toks, enc)
    caches = encdec.init_cache(cfg, b, s, dtype=jnp.float32)
    cross = encdec.precompute_cross_kv(params, cfg, enc)
    for t in range(s):
        lg, caches = encdec.decode_step(params, cfg, toks[:, t : t + 1], caches, cross)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, t]), rtol=1e-3, atol=2e-4
        )


def test_scan_layout_equals_unrolled():
    for arch in ["qwen2.5-3b", "deepseek-v2-lite-16b", "recurrentgemma-9b"]:
        cfg = smoke_config(arch)
        model = get_model(cfg)
        params = model.init(KEY)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
        a, _ = lm.forward(params, cfg, toks)
        b_, _ = lm.forward(params, cfg, toks, layout_scan=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-5)


def test_chunked_attention_equals_full():
    cfg = smoke_config("qwen2.5-3b")
    model = get_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    a, _ = lm.forward(params, cfg, toks, attn_impl="full")
    b_, _ = lm.forward(params, cfg, toks, attn_impl="chunked")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-3, atol=2e-4)


def test_decoder_graph_builder_matches_forward():
    """The plan-compiler lowering (`models/transformer_graph.py`) is pinned
    to the model-level oracle: compiling the prefill graph on the reference
    backend reproduces ``lm.forward`` exactly, the cache spec mirrors the
    config, and unsupported families refuse loudly instead of mis-lowering."""
    from repro.core.graph import compile_plan
    from repro.models.transformer_graph import (
        build_decoder_graph,
        decoder_cache_spec,
    )

    cfg = smoke_config("qwen2.5-3b")
    params = lm.init_lm(KEY, cfg)
    g = build_decoder_graph(params, cfg, phase="prefill")
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    outs = compile_plan(g, backend="reference")(
        g.params, toks, pos, jnp.full((b,), s, jnp.int32)
    )
    want, _ = lm.forward(params, cfg, toks)
    np.testing.assert_allclose(
        np.asarray(outs[0][..., : cfg.vocab]), np.asarray(want),
        rtol=1e-5, atol=1e-5,
    )
    # logits + per-layer (k, v) streams for the paged cache
    assert len(outs) == 1 + 2 * cfg.n_layers
    spec = decoder_cache_spec(cfg)
    assert spec == {
        "n_layers": cfg.n_layers,
        "n_kv_heads": cfg.n_kv_heads,
        "head_dim": cfg.head_dim,
    }
    # non-GQA families must refuse (never silently mis-lower)
    for bad in ("deepseek-v2-lite-16b", "mamba2-1.3b", "qwen3-14b"):
        bad_cfg = smoke_config(bad)
        bad_model = get_model(bad_cfg)
        with pytest.raises(NotImplementedError):
            build_decoder_graph(bad_model.init(KEY), bad_cfg)


def test_long_context_skip_rules():
    cells = {a: shape_cells(a) for a in ARCH_IDS}
    assert cells["mamba2-1.3b"]["long_500k"] == "run"
    assert cells["recurrentgemma-9b"]["long_500k"] == "run"
    for a in ("qwen2.5-3b", "deepseek-v2-236b", "paligemma-3b", "whisper-small"):
        assert cells[a]["long_500k"].startswith("SKIP")
    # every arch runs all non-long shapes (whisper is enc-dec, not enc-only)
    for a in ARCH_IDS:
        for sh in ("train_4k", "prefill_32k", "decode_32k"):
            assert cells[a][sh] == "run"


def test_pruned_linear_modes_agree():
    """The paper's technique inside a transformer: masked == bsr == colpack."""
    from repro.core.pruning import Block, Column, project
    from repro.core.sparse import ColumnCompact, PBCSR
    from repro.models.layers import linear

    w = jax.random.normal(KEY, (256, 384)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
    wp, mask = project(w, Block(0.5, bm=128, bn=128))
    fmt = PBCSR.from_dense(wp, mask, 128, 128)
    y_masked = linear({"w": w, "mask": mask}, x, mode="masked")
    y_bsr = linear({"values": fmt.values, "block_rows": fmt.block_rows}, x, mode="bsr")
    np.testing.assert_allclose(np.asarray(y_bsr), np.asarray(y_masked), rtol=1e-4, atol=1e-4)

    wp2, mask2 = project(w, Column(0.5))
    cc = ColumnCompact.from_dense(wp2, mask2)
    y_masked2 = linear({"w": w, "mask": mask2}, x, mode="masked")
    y_col = linear({"values": cc.values, "kept": cc.kept}, x, mode="colpack")
    np.testing.assert_allclose(np.asarray(y_col), np.asarray(y_masked2), rtol=1e-4, atol=1e-4)
