"""LR-DSL graph compiler: passes preserve semantics, sparse substitution is
exact, Table-1-style pipelines lower through both jnp and Pallas paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import GraphBuilder, dce, fold_norm, fuse_activation, lower, optimize
from repro.core.pruning import Block, Channel, Column, PatternKernel, project

KEY = jax.random.PRNGKey(0)


def _mlp_graph():
    b = GraphBuilder(["x"])
    ws = [jax.random.normal(jax.random.PRNGKey(i + 10), s) * 0.05
          for i, s in enumerate([(256, 512), (512, 384), (384, 256), (256, 128)])]
    bs = [jax.random.normal(jax.random.PRNGKey(i + 20), (s[1],)) * 0.1
          for i, s in enumerate([(256, 512), (512, 384), (384, 256), (256, 128)])]
    h = b.add("linear", "x", name="l1", params={"w": ws[0], "b": bs[0]}, activation="relu")
    h = b.add("linear", h, name="l2", params={"w": ws[1], "b": bs[1]}, activation="gelu")
    h = b.add("linear", h, name="l3", params={"w": ws[2], "b": bs[2]})
    h = b.add("linear", h, name="l4", params={"w": ws[3], "b": bs[3]})
    return b.build(h)


def test_fold_norm_conv_bn_relu():
    b = GraphBuilder(["x"])
    w1 = jax.random.normal(KEY, (16, 3, 3, 3)) * 0.1
    c1 = b.add("conv2d", "x", name="c1", params={"w": w1}, stride=1, padding="SAME")
    n1 = b.add("norm", c1, name="bn1", params={
        "scale": jnp.ones(16) * 1.5, "bias": jnp.ones(16) * 0.2,
        "mean": jnp.zeros(16) + 0.1, "var": jnp.ones(16) * 2.0}, kind="batch")
    a1 = b.add("activation", n1, name="act1", fn="relu")
    g = b.build(a1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 16, 16))
    y0 = lower(g, use_kernels=False)(g.params, x)
    g2 = optimize(g)
    # BN + act nodes folded away, activation fused into conv
    assert [n.op for n in g2.nodes] == ["conv2d"]
    assert g2.nodes[0].attrs["activation"] == "relu"
    y1 = lower(g2, use_kernels=False)(g2.params, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-4, atol=1e-5)


def test_fuse_activation_skipped_when_multi_consumer():
    b = GraphBuilder(["x"])
    l1 = b.add("linear", "x", name="l1", params={"w": jnp.eye(8)})
    a1 = b.add("activation", l1, name="a1", fn="relu")
    l2 = b.add("linear", l1, name="l2", params={"w": jnp.eye(8)})  # 2nd consumer
    out = b.add("add", (a1, l2), name="out")
    g = b.build(out)
    g2 = fuse_activation(g)
    assert any(n.op == "activation" for n in g2.nodes), "must not fuse across fanout"


@pytest.mark.parametrize("use_kernels", [False, True])
def test_sparse_substitution_pipeline_exact(use_kernels):
    g = _mlp_graph()
    sts = {
        "l1": Block(0.5, bm=128, bn=128, balanced=False),
        "l2": Column(0.5),
        "l3": Channel(0.5),
    }
    masks = {k: project(g.params[k]["w"], v)[1] for k, v in sts.items()}
    # masked-dense reference; channel pruning removes bias too (contract)
    pm = {}
    for k, v in g.params.items():
        if k in masks:
            w = v["w"] * masks[k]
            bb = v["b"]
            if isinstance(sts[k], Channel):
                bb = bb * jnp.any(masks[k] != 0, axis=0)
            pm[k] = {"w": w, "b": bb}
        else:
            pm[k] = v
    x = jax.random.normal(jax.random.PRNGKey(30), (8, 256))
    y_ref = lower(g, use_kernels=False)(pm, x)
    go = optimize(g, masks, sts)
    y = lower(go, use_kernels=use_kernels)(go.params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-3, atol=1e-3)
    ops = {n.name: n.op for n in go.nodes}
    assert ops["l1"] == "sparse_linear" and ops["l3"] == "sparse_linear"


def test_pattern_conv_substitution():
    b = GraphBuilder(["x"])
    w = jax.random.normal(KEY, (8, 4, 3, 3)) * 0.2
    c = b.add("conv2d", "x", name="c1", params={"w": w}, stride=1, padding="SAME")
    g = b.build(c)
    st_ = PatternKernel(connectivity=0.25)
    mask = project(w, st_)[1]
    go = optimize(g, {"c1": mask}, {"c1": st_})
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 8, 8))
    y_ref = lower(g, use_kernels=False)({"c1": {"w": w * mask}}, x)
    y = lower(go, use_kernels=False)(go.params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-5)


def test_dce_removes_dead_branch():
    b = GraphBuilder(["x"])
    live = b.add("linear", "x", name="live", params={"w": jnp.eye(8)})
    b.add("linear", "x", name="dead", params={"w": jnp.eye(8)})
    g = b.build(live)
    g2 = dce(g)
    assert [n.name for n in g2.nodes] == ["live"]
    assert "dead" not in g2.params


def test_storage_shrinks_after_optimize():
    """Compiler output must be smaller on disk than masked dense."""
    g = _mlp_graph()
    sts = {"l2": Column(0.6)}
    masks = {"l2": project(g.params["l2"]["w"], sts["l2"])[1]}
    go = optimize(g, masks, sts)
    import numpy as _np

    before = sum(_np.asarray(v).nbytes for v in jax.tree.leaves(g.params))
    after = sum(_np.asarray(v).nbytes for v in jax.tree.leaves(go.params))
    assert after < before
