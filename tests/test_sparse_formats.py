"""Sparse storage formats + matrix reorder: round-trips, storage wins, bands."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - fallback keeps collection alive
    from _hypothesis_fallback import given, settings, st

from repro.core.pruning import Block, Channel, Column, Unstructured, project
from repro.core.sparse import (
    CSR,
    ChannelCompact,
    ColumnCompact,
    PBCSR,
    apply_column_perm,
    balance_stats,
    block_mask,
    dense_nbytes,
    fold_perm_into_next,
    pack_balanced,
    plan_reorder,
    unpack_balanced,
)

KEY = jax.random.PRNGKey(0)


def test_pbcsr_roundtrip_balanced():
    w = jax.random.normal(KEY, (512, 768))
    wp, m = project(w, Block(0.5, bm=128, bn=128))
    fmt = PBCSR.from_dense(w, m, 128, 128)
    np.testing.assert_allclose(np.asarray(fmt.to_dense()), np.asarray(wp), rtol=1e-6)
    assert fmt.padded_blocks == 0  # balanced projection -> no padding


def test_pbcsr_roundtrip_unbalanced_has_padding():
    w = jax.random.normal(KEY, (512, 768))
    wp, m = project(w, Block(0.6, bm=128, bn=128, balanced=False))
    fmt = PBCSR.from_dense(w, m, 128, 128)
    np.testing.assert_allclose(np.asarray(fmt.to_dense()), np.asarray(wp), rtol=1e-6)


def test_pbcsr_storage_beats_csr():
    """The paper's claim: structured storage beats CSR.  One int32 per block
    vs one per element."""
    w = jax.random.normal(KEY, (512, 512)).astype(jnp.float32)
    wp, m = project(w, Block(0.5, bm=128, bn=128))
    pb = PBCSR.from_dense(wp, m, 128, 128)
    csr = CSR.from_dense(np.asarray(wp), np.asarray(m))
    dense = dense_nbytes((512, 512), jnp.float32)
    assert pb.nbytes < csr.nbytes < dense * 1.5
    # index overhead: PBCSR ~1 int per 16K weights
    assert pb.nbytes - pb.n_blocks * 128 * 128 * 4 == pb.n_blocks * 4


def test_column_compact_apply_and_storage():
    w = jax.random.normal(KEY, (256, 128))
    wp, m = project(w, Column(0.6))
    cc = ColumnCompact.from_dense(wp, m)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
    np.testing.assert_allclose(
        np.asarray(cc.apply(x)), np.asarray(x @ wp), rtol=1e-4, atol=1e-4
    )
    assert cc.nbytes < dense_nbytes((256, 128), w.dtype) * 0.6


def test_channel_compact_scatter():
    w = jax.random.normal(KEY, (64, 96))
    wp, m = project(w, Channel(0.5))
    ch = ChannelCompact.from_dense(wp, m)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    np.testing.assert_allclose(
        np.asarray(ch.scatter(ch.apply(x))), np.asarray(x @ wp), rtol=1e-4, atol=1e-4
    )


@given(st.integers(0, 5))
@settings(max_examples=6, deadline=None)
def test_pack_unpack_roundtrip(seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (256, 384))
    wp, m = project(w, Block(0.5, bm=64, bn=128, balanced=False))
    bm = np.asarray(block_mask(m, 64, 128))
    vals, rows = pack_balanced(np.asarray(wp), bm, 64, 128)
    back = unpack_balanced(vals, rows, (256, 384), 64, 128)
    np.testing.assert_allclose(np.asarray(back), np.asarray(wp), rtol=1e-6)


# --------------------------------------------------------------------------- #
# reorder                                                                      #
# --------------------------------------------------------------------------- #


def test_reorder_reduces_waste():
    bmask = np.zeros((8, 12), bool)
    rng = np.random.default_rng(0)
    for j in range(12):  # deliberately imbalanced columns
        c = rng.integers(1, 8)
        bmask[rng.choice(8, c, replace=False), j] = True
    before = balance_stats(bmask)["waste_frac"]
    plan = plan_reorder(bmask, max_bands=4)
    assert plan.waste_after <= before + 1e-9
    # bands cover all columns exactly once
    cols = sorted(sum(([b.start, b.stop] for b in plan.bands), []))
    assert cols[0] == 0 and cols[-1] == 12


def test_reorder_band_capacity_is_sufficient():
    bmask = np.zeros((4, 6), bool)
    for j, c in enumerate([0, 1, 2, 2, 2, 3]):
        bmask[:c, j] = True
    plan = plan_reorder(bmask, max_bands=3)
    counts = bmask.sum(axis=0)[plan.order]
    for b in plan.bands:
        assert (counts[b.start : b.stop] <= b.count).all()


def test_perm_fold_exactness():
    """Permuting layer-L outputs + folding into layer L+1 == identity."""
    w = jax.random.normal(KEY, (64, 256))
    wn = jax.random.normal(jax.random.PRNGKey(1), (256, 32))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
    order = np.random.default_rng(0).permutation(4).astype(np.int32)  # block cols of 64
    y = x @ w
    y_perm = apply_column_perm(y, order, 64)
    wn_fold = fold_perm_into_next(wn, order, 64)
    np.testing.assert_allclose(
        np.asarray(y_perm @ wn_fold), np.asarray(y @ wn), rtol=2e-3, atol=2e-3
    )
