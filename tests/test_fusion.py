"""Kernel-level fusion (PR 2): the fused-elementwise Pallas kernel vs the
jnp reference handler, GEMM epilogue-program fusion (``fuse_epilogue``),
epilogue-aware memory estimates, and batched plan serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import (
    DEFAULT_PIPELINE,
    BatchedPlan,
    GraphBuilder,
    compile_plan,
    fuse_elementwise,
    fuse_epilogue,
    optimize,
)
from repro.core.graph.ir import Graph, Node
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models.cnn import APPS, app_masks
from repro.serving.engine import PlanServer

KEY = jax.random.PRNGKey(0)

APP_INPUTS = {
    "style_transfer": (1, 3, 16, 16),
    "coloring": (1, 1, 16, 16),
    "super_resolution": (1, 3, 8, 8),
}

#: the pipeline with *all* epilogue fusion off (fuse_activation is the
#: single-activation special case of fuse_epilogue) -- the unfused baseline
NO_EPILOGUE = tuple(
    p for p in DEFAULT_PIPELINE if p not in ("fuse_activation", "fuse_epilogue")
)


# --------------------------------------------------------------------------- #
# fused-elementwise Pallas kernel vs reference                                 #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize(
    "shape", [(4, 16), (5, 37), (2, 3, 19), (1, 128), (3, 200)]
)
def test_fused_elementwise_kernel_parity_odd_shapes(shape):
    """All step kinds, including layer norm over non-128-multiple dims."""
    d = shape[-1]
    x = jax.random.normal(KEY, shape)
    r = jax.random.normal(jax.random.PRNGKey(1), shape)
    s = jax.random.normal(jax.random.PRNGKey(2), shape)
    scale = jax.random.normal(jax.random.PRNGKey(3), (d,)) * 0.1 + 1.0
    bias = jax.random.normal(jax.random.PRNGKey(4), (d,)) * 0.1
    steps = (("activation", "gelu"), ("add", 0), ("mul", 1), ("norm", 0, 1e-5))
    got = kops.fused_elementwise(x, [r, s], steps, [(scale, bias)], interpret=True)
    want = kref.fused_elementwise_ref(x, [r, s], steps, [(scale, bias)])
    assert got.shape == shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fused_elementwise_node_kernel_vs_reference_backend():
    """A fused_elementwise node executes through the Pallas kernel on the
    kernel backend and through the jnp interpreter on reference -- same
    answer (graph-step indices, norm params by pkey)."""
    b = GraphBuilder(["x", "y"])
    h = b.add("add", ("x", "y"), name="a1")
    h = b.add("activation", h, name="act1", fn="silu")
    h = b.add("mul", (h, "y"), name="m1")
    h = b.add("norm", h, name="ln1", kind="layer",
              params={"scale": jnp.ones(24) * 1.2, "bias": jnp.ones(24) * 0.3})
    g = fuse_elementwise(b.build(h))
    assert [n.op for n in g.nodes] == ["fused_elementwise"]
    x = jax.random.normal(KEY, (6, 24))
    y = jax.random.normal(jax.random.PRNGKey(1), (6, 24))
    got = compile_plan(g, backend="kernel", interpret=True)(g.params, x, y)
    want = compile_plan(g, backend="reference")(g.params, x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fused_elementwise_kernel_falls_back_on_broadcast_sides():
    """Sides that only broadcast (not same-shape) cannot stream per-tile;
    the kernel handler must fall back to the interpreter, not crash."""
    n1 = Node(op="fused_elementwise", name="f", inputs=("x", "y"),
              attrs={"steps": (("add", 1), ("activation", "relu"))})
    g = Graph(nodes=[n1], inputs=("x", "y"), outputs=("f",))
    x = jax.random.normal(KEY, (4, 16))
    y = jax.random.normal(jax.random.PRNGKey(1), (16,))  # broadcasts over rows
    got = compile_plan(g, backend="kernel", interpret=True)(g.params, x, y)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(jax.nn.relu(x + y)), rtol=1e-6
    )


@pytest.mark.parametrize("app", list(APPS))
def test_app_plans_kernel_vs_reference_backend(app):
    """Full compiled plans (epilogue attrs included) agree across backends
    on the paper's three apps (Pallas in interpret mode)."""
    g = APPS[app](KEY, base=8)
    masks, structures = app_masks(g, app, sparsity=0.5)
    go = optimize(g, masks, structures)
    x = jax.random.normal(jax.random.PRNGKey(1), APP_INPUTS[app])
    got = compile_plan(go, backend="kernel", interpret=True)(go.params, x)
    want = compile_plan(go, backend="reference")(go.params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_fused_elementwise_tuning_cache_key():
    cache = kops.tuning_cache()
    prev_enabled, prev_entries = cache.enabled, dict(cache.entries)
    cache.clear()
    cache.enabled = False
    try:
        x = jax.random.normal(KEY, (8, 48))
        kops.fused_elementwise(x, [x], (("add", 0),), interpret=True)
        # side/norm counts are part of the key: same-shape programs with
        # different operand counts must never share a swept winner
        key = kops.TuningCache.key(
            "fused_elementwise", 8, 48, 1, jnp.float32, "ew+s1n0", True
        )
        assert key in cache.entries
        # interpret mode seeds a single full-M tile (one grid step: each
        # step costs ~1 ms of Python there), not the hw 128-row default
        assert cache.entries[key].blocks == (8,)
    finally:
        cache.enabled = prev_enabled
        cache.entries = prev_entries


# --------------------------------------------------------------------------- #
# fuse_epilogue                                                                #
# --------------------------------------------------------------------------- #


def _linear_chain_graph(n=32):
    b = GraphBuilder(["x", "r"])
    l1 = b.add("linear", "x", name="l1",
               params={"w": jax.random.normal(KEY, (n, n)) * 0.1,
                       "b": jnp.zeros(n)})
    h = b.add("activation", l1, name="act", fn="gelu")
    h = b.add("add", (h, "r"), name="res")
    return b.build(h)


def test_fuse_epilogue_folds_into_linear():
    g = _linear_chain_graph()
    gf = fuse_epilogue(g)
    assert [n.op for n in gf.nodes] == ["linear"]
    fused = gf.nodes[0]
    assert fused.name == "res"  # keeps the tail's name
    assert fused.attrs["epilogue"] == (("activation", "gelu"), ("add", 1))
    assert "w" in gf.params["res"] and "l1" not in gf.params
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    r = jax.random.normal(jax.random.PRNGKey(2), (4, 32))
    want = compile_plan(g, backend="reference")(g.params, x, r)
    for backend, interp in (("reference", None), ("kernel", True)):
        got = compile_plan(gf, backend=backend, interpret=interp)(gf.params, x, r)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_fuse_epilogue_fixpoint_conv_norm_act_add():
    """conv -> instance norm -> relu -> residual add collapses to one conv
    node with a 3-step epilogue (the style-transfer block shape)."""
    b = GraphBuilder(["x"])
    c0 = b.add("conv2d", "x", name="c0",
               params={"w": jax.random.normal(KEY, (4, 4, 3, 3)) * 0.1})
    c1 = b.add("conv2d", c0, name="c1",
               params={"w": jax.random.normal(jax.random.PRNGKey(1), (4, 4, 3, 3)) * 0.1})
    h = b.add("norm", c1, name="in1", kind="instance",
              params={"scale": jnp.ones(4) * 1.4, "bias": jnp.ones(4) * 0.1})
    h = b.add("activation", h, name="a1", fn="relu")
    h = b.add("add", (c0, h), name="res")
    g = b.build(h)
    gf = fuse_epilogue(g)
    ops = [n.op for n in gf.nodes]
    assert ops == ["conv2d", "conv2d"], ops
    epi = gf.nodes[-1].attrs["epilogue"]
    assert [s[0] for s in epi] == ["norm_instance", "activation", "add"]
    assert "e0_scale" in gf.params["res"]
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 8, 8))
    got = compile_plan(gf, backend="reference")(gf.params, x)
    want = compile_plan(g, backend="reference")(g.params, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fuse_epilogue_respects_fanout_and_outputs():
    # fanout: the GEMM output feeds two consumers -> no fold
    b = GraphBuilder(["x"])
    l1 = b.add("linear", "x", name="l1", params={"w": jnp.eye(8)})
    a1 = b.add("activation", l1, name="a1", fn="relu")
    a2 = b.add("activation", l1, name="a2", fn="tanh")
    out = b.add("add", (a1, a2), name="out")
    g = b.build(out)
    assert any(n.op == "activation" for n in fuse_epilogue(g).nodes)
    # graph output: the GEMM's name is externally visible -> no fold
    b = GraphBuilder(["x"])
    l1 = b.add("linear", "x", name="l1", params={"w": jnp.eye(8)})
    a1 = b.add("activation", l1, name="a1", fn="relu")
    g = b.build((l1, a1))
    assert len(fuse_epilogue(g).nodes) == 2


def test_fuse_epilogue_skips_step_referencing_raw_gemm_output():
    """relu(l1) + l1 needs the pre-step value as a side: not expressible as
    a running-value epilogue, so the fused_elementwise node must survive."""
    b = GraphBuilder(["x"])
    l1 = b.add("linear", "x", name="l1", params={"w": jnp.eye(8)})
    a1 = b.add("activation", l1, name="a1", fn="relu")
    res = b.add("add", (a1, l1), name="res")
    g = fuse_elementwise(b.build(res))
    assert [n.op for n in g.nodes] == ["linear", "fused_elementwise"]
    gf = fuse_epilogue(g)
    assert [n.op for n in gf.nodes] == ["linear", "fused_elementwise"]


@pytest.mark.parametrize("app", list(APPS))
def test_fuse_epilogue_reduces_steps_and_matches_on_apps(app):
    """Acceptance: epilogue fusion shrinks every demo app's plan and the
    outputs match the unfused plan to f32 tolerance."""
    g = APPS[app](KEY, base=16)
    masks, structures = app_masks(g, app, sparsity=0.5)
    go = optimize(g, masks, structures)
    go0 = optimize(g, masks, structures, pipeline=NO_EPILOGUE)
    plan = compile_plan(go, backend="reference")
    plan0 = compile_plan(go0, backend="reference")
    assert len(plan.steps) < len(plan0.steps), (len(plan.steps), len(plan0.steps))
    x = jax.random.normal(jax.random.PRNGKey(1), APP_INPUTS[app])
    np.testing.assert_allclose(
        np.asarray(plan(go.params, x)),
        np.asarray(plan0(go0.params, x)),
        rtol=1e-5, atol=1e-5,
    )


def test_memory_estimate_epilogue_not_double_counted():
    """Folded steps must not appear as resident intermediates: the fused
    plan's estimate drops the follower buffers and its peak never exceeds
    the unfused plan's."""
    g = _linear_chain_graph(n=64)
    gf = fuse_epilogue(g)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    r = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    mem0 = compile_plan(g, backend="reference").memory_estimate(x, r)
    mem1 = compile_plan(gf, backend="reference").memory_estimate(x, r)
    names1 = [n for n, _, _ in mem1["per_step"]]
    assert names1 == ["res"]  # l1/act intermediates gone from the schedule
    assert mem1["peak_activation_bytes"] <= mem0["peak_activation_bytes"]
    assert mem1["out_structs"][0].shape == (8, 64)


# --------------------------------------------------------------------------- #
# batched plan execution + serving                                             #
# --------------------------------------------------------------------------- #


def _small_app_plan():
    g = APPS["super_resolution"](KEY, base=8)
    go = optimize(g)
    return go, compile_plan(go, backend="reference")


def test_batched_plan_pads_remainder_and_matches_plain_plan():
    go, plan = _small_app_plan()
    bp = plan.batched(2)
    assert isinstance(bp, BatchedPlan)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 3, 8, 8))
    got = bp(go.params, x)
    want = plan(go.params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert bp.last_stats == {"frames": 5, "batches": 3, "padded_frames": 1}


def test_batched_plan_exact_multiple_no_padding():
    go, plan = _small_app_plan()
    bp = plan.batched(2)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 8, 8))
    bp(go.params, x)
    assert bp.last_stats == {"frames": 4, "batches": 2, "padded_frames": 0}


def test_batched_plan_via_vmap_matches_native():
    b = GraphBuilder(["x"])
    h = b.add("linear", "x", name="l1",
              params={"w": jax.random.normal(KEY, (16, 16)) * 0.1})
    h = b.add("activation", h, name="a1", fn="relu")
    g = b.build(h)
    plan = compile_plan(g, backend="reference")
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 4, 16))
    got = plan.batched(3, via_vmap=True)(g.params, x)
    want = plan.batched(3)(g.params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_batched_plan_rejects_bad_args():
    go, plan = _small_app_plan()
    with pytest.raises(ValueError, match="batch_size"):
        plan.batched(0)
    with pytest.raises(TypeError, match="at least one input"):
        plan.batched(2)({})
    with pytest.raises(ValueError, match="empty macro-batch"):
        plan.batched(2)(go.params, jnp.zeros((0, 3, 8, 8)))


def test_plan_server_queue_and_stats():
    go, plan = _small_app_plan()
    server = PlanServer(plan, go.params, batch_size=4)
    frames = [jax.random.normal(jax.random.PRNGKey(i), (3, 8, 8)) for i in range(6)]
    for f in frames:
        server.submit(f)
    assert server.pending == 6
    out = server.flush()
    assert server.pending == 0
    assert out.shape[0] == 6
    assert server.stats == {
        "frames": 6, "batches": 2, "padded_frames": 2, "deadline_flushes": 0,
    }
    want = plan(go.params, jnp.stack(frames))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert server.flush() is None  # empty queue is a no-op
    with pytest.raises(TypeError, match="inputs per frame"):
        server.submit(frames[0], frames[0])


def test_plan_server_close_flushes_partial_batch():
    """Queued frames must never be dropped: close() drains a partial tail
    batch (smaller than batch_size) and refuses further submits."""
    go, plan = _small_app_plan()
    server = PlanServer(plan, go.params, batch_size=4)
    frames = [jax.random.normal(jax.random.PRNGKey(i), (3, 8, 8)) for i in range(3)]
    for f in frames:
        server.submit(f)
    assert server.pending == 3  # strictly less than one full batch
    out = server.close()
    assert server.pending == 0 and server.closed
    assert out.shape[0] == 3
    want = plan(go.params, jnp.stack(frames))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert server.stats["frames"] == 3 and server.stats["padded_frames"] == 1
    with pytest.raises(RuntimeError, match="closed"):
        server.submit(frames[0])
    assert server.close() is None  # idempotent


def test_plan_server_context_manager_drains_queue():
    go, plan = _small_app_plan()
    with PlanServer(plan, go.params, batch_size=4) as server:
        server.submit(jax.random.normal(KEY, (3, 8, 8)))
        assert server.pending == 1
    assert server.closed and server.pending == 0
    assert server.stats["frames"] == 1  # the exit flush ran it


def test_plan_server_flush_after_deadline_flushes_partial_batch():
    """Low-traffic serving: once the oldest queued frame has waited past the
    deadline, the next submit auto-flushes the partial batch instead of
    blocking on batch fill."""
    go, plan = _small_app_plan()
    now = [0.0]
    server = PlanServer(
        plan, go.params, batch_size=4, flush_after=1.0, clock=lambda: now[0]
    )
    f0 = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 8))
    f1 = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 8))
    server.submit(f0)
    assert server.pending == 1 and not server.completed  # under deadline
    now[0] = 0.5
    assert server.poll() is None  # still under deadline
    now[0] = 1.2  # the *oldest* frame is now past the deadline
    server.submit(f1)  # joins the flush triggered by its own submit
    assert server.pending == 0
    assert len(server.completed) == 1
    (out,) = server.drain_completed()  # hand over + clear the buffer
    assert not server.completed
    assert out.shape[0] == 2
    want = plan(go.params, jnp.stack([f0, f1]))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert server.stats["deadline_flushes"] == 1
    assert server.stats["frames"] == 2 and server.stats["padded_frames"] == 2


def test_plan_server_flush_after_poll_without_submit():
    """A lone frame must never be stranded: an idle-loop poll() flushes it
    once the deadline passes, and the timer re-arms for the next frame."""
    go, plan = _small_app_plan()
    now = [0.0]
    server = PlanServer(
        plan, go.params, batch_size=4, flush_after=0.5, clock=lambda: now[0]
    )
    assert server.poll() is None  # empty queue: no-op
    server.submit(jax.random.normal(KEY, (3, 8, 8)))
    now[0] = 0.6
    out = server.poll()
    assert out is not None and out.shape[0] == 1
    assert server.completed == []  # poll hands outputs back, never buffers
    assert server.poll() is None  # queue drained; deadline timer reset
    # a fresh frame restarts the deadline from its own submit time
    server.submit(jax.random.normal(KEY, (3, 8, 8)))
    assert server.poll() is None
    now[0] = 1.2
    assert server.poll() is not None
    assert server.stats["deadline_flushes"] == 2


def test_plan_server_flush_after_close_interaction():
    """close() drains regardless of the deadline (queued frames are never
    dropped), and a closed server's poll() is a no-op."""
    go, plan = _small_app_plan()
    now = [0.0]
    server = PlanServer(
        plan, go.params, batch_size=4, flush_after=10.0, clock=lambda: now[0]
    )
    f0 = jax.random.normal(KEY, (3, 8, 8))
    server.submit(f0)
    out = server.close()  # deadline nowhere near expired: close still drains
    assert out is not None and out.shape[0] == 1 and server.closed
    assert server.stats["deadline_flushes"] == 0  # manual close, not deadline
    assert server.poll() is None  # closed server: no-op
    with pytest.raises(RuntimeError, match="closed"):
        server.submit(f0)


# --------------------------------------------------------------------------- #
# PBCSR band kernel: epilogue step programs in-tile                            #
# --------------------------------------------------------------------------- #


def _pbcsr_setup(key, k=256, n=384, m=64, sparsity=0.5, balanced=True):
    from repro.core.pruning import Block, project
    from repro.core.sparse import PBCSR

    w = jax.random.normal(key, (k, n)) * 0.05
    wp, mask = project(w, Block(sparsity, bm=128, bn=128, balanced=balanced))
    fmt = PBCSR.from_dense(wp, mask, 128, 128)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k))
    return wp, fmt, x


def test_bsr_epilogue_program_matches_jnp_tail():
    wp, fmt, x = _pbcsr_setup(jax.random.PRNGKey(7))
    n = wp.shape[1]
    b = jax.random.normal(jax.random.PRNGKey(8), (n,))
    side = jax.random.normal(jax.random.PRNGKey(9), (x.shape[0], n))
    steps = (("add", 0), ("activation", "gelu"), ("mul", 0))
    got = kops.bsr_matmul(
        x, fmt.values, fmt.block_rows, b, activation="relu",
        epilogue=steps, epilogue_sides=(side,),
    )
    tail = kops.bsr_matmul(x, fmt.values, fmt.block_rows, b, activation="relu")
    want = kref.apply_steps_ref(tail, steps, [side])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_bsr_epilogue_banded_with_empty_band():
    """Band dispatch slices the epilogue sides per band; a zero-count band
    (pure bias/activation/epilogue of zeros) must honor the program too."""
    from repro.core.sparse import PBCSR, block_mask, plan_reorder, apply_column_perm
    from repro.core.pruning import Block, project

    k, n, m = 512, 768, 64
    w = jax.random.normal(jax.random.PRNGKey(3), (k, n)) * 0.05
    wp, mask = project(w, Block(0.6, bm=128, bn=128, balanced=False))
    # force one fully-dead block column so a zero-count band exists
    mask = mask.at[:, :128].set(0)
    wp = wp * mask
    bm_ = np.asarray(block_mask(mask, 128, 128))
    plan = plan_reorder(bm_, max_bands=3)
    w_perm = apply_column_perm(wp, plan.order, 128)
    m_perm = apply_column_perm(mask, plan.order, 128)
    fmt = PBCSR.from_dense(w_perm, m_perm, 128, 128)
    bands = [(b.start, b.stop, b.count) for b in plan.bands]
    assert any(c == 0 for _, _, c in bands)
    x = jax.random.normal(KEY, (m, k))
    b = jax.random.normal(jax.random.PRNGKey(8), (n,))
    side = jax.random.normal(jax.random.PRNGKey(9), (m, n))
    steps = (("add", 0), ("activation", "tanh"))
    got = kops.bsr_matmul(
        x, fmt.values, fmt.block_rows, b, bands=bands,
        epilogue=steps, epilogue_sides=(side,),
    )
    want = kref.apply_steps_ref(kref.matmul_ref(x, w_perm, b), steps, [side])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_bsr_epilogue_tunes_under_its_own_key():
    wp, fmt, x = _pbcsr_setup(jax.random.PRNGKey(11))
    n = wp.shape[1]
    side = jax.random.normal(jax.random.PRNGKey(9), (x.shape[0], n))
    cache = kops.tuning_cache()
    prev = dict(cache.entries)
    try:
        kops.bsr_matmul(x, fmt.values, fmt.block_rows)
        kops.bsr_matmul(
            x, fmt.values, fmt.block_rows,
            epilogue=(("add", 0),), epilogue_sides=(side,),
        )
        keys = [k_ for k_ in cache.entries if k_.startswith("bsr_matmul|")]
        fmts = {k_.split("|")[3] for k_ in keys}
        assert "pbcsr" in fmts and "pbcsr+e1s1" in fmts
    finally:
        cache.entries = prev


def test_pbcsr_plan_executes_epilogue_in_kernel(monkeypatch):
    """A sparse_linear(pbcsr) node with a tile-fusable epilogue must reach
    the Pallas kernel as a step program, not the jnp tail."""
    from repro.core.pruning import Block, project
    from repro.core.sparse import PBCSR

    k, n = 256, 256
    w = jax.random.normal(KEY, (k, n)) * 0.05
    wp, mask = project(w, Block(0.5, bm=128, bn=128))
    fmt = PBCSR.from_dense(wp, mask, 128, 128)
    nodes = [
        Node("sparse_linear", "sp", ("x",), {"format": "pbcsr"}),
        Node("add", "res", ("sp", "skip")),
        Node("activation", "act", ("res",), {"fn": "relu"}),
    ]
    g = Graph(
        nodes=nodes, inputs=("x", "skip"), outputs=("act",),
        params={"sp": {"values": fmt.values, "block_rows": fmt.block_rows}},
    )
    gf = fuse_epilogue(g)
    (node,) = [nd for nd in gf.nodes if nd.op == "sparse_linear"]
    assert node.attrs["epilogue"] == (("add", 1), ("activation", "relu"))
    seen = {}
    real = kops.bsr_matmul

    def spy(*a, **kw):
        seen.update(kw)
        return real(*a, **kw)

    monkeypatch.setattr(kops, "bsr_matmul", spy)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, k))
    skip = jax.random.normal(jax.random.PRNGKey(3), (64, n))
    got = compile_plan(gf, backend="kernel")(gf.params, x, skip)
    assert seen.get("epilogue"), "epilogue did not reach the Pallas kernel"
    want = compile_plan(g, backend="reference")(g.params, x, skip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
