"""Unit + property tests for the ADMM structured-pruning core."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - fallback keeps collection alive
    from _hypothesis_fallback import given, settings, st

from repro.core.pruning import (
    AdmmConfig,
    BankBalanced,
    Block,
    Channel,
    Column,
    NM,
    PatternKernel,
    PrunePlan,
    Row,
    Unstructured,
    admm_init,
    admm_penalty,
    admm_update,
    apply_masks,
    convergence_metrics,
    hard_prune,
    mask_for,
    project,
    topk_mask,
    tree_sparsity_report,
)


KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------- #
# projections                                                                  #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize(
    "structure,shape",
    [
        (Unstructured(0.75), (64, 96)),
        (Row(0.5), (64, 96)),
        (Column(0.5), (64, 96)),
        (Channel(0.5), (64, 96)),
        (Block(0.5, bm=16, bn=16), (64, 96)),
        (Block(0.5, bm=16, bn=16, balanced=False), (64, 96)),
        (NM(n_keep=2, m=4), (64, 96)),
        (BankBalanced(0.5, bank=32), (64, 96)),
    ],
)
def test_projection_basic(structure, shape):
    w = jax.random.normal(KEY, shape)
    wp, mask = project(w, structure)
    # projected = w * mask exactly
    np.testing.assert_allclose(np.asarray(wp), np.asarray(w * mask), rtol=1e-6)
    # mask is 0/1
    assert set(np.unique(np.asarray(mask))).issubset({0.0, 1.0})
    # idempotent: projecting the projection changes nothing
    wp2, _ = project(wp, structure)
    np.testing.assert_allclose(np.asarray(wp2), np.asarray(wp), rtol=1e-6)


def test_projection_is_euclidean_optimal_for_rows():
    """The kept rows must be exactly the top-|sparsity| rows by L2 norm."""
    w = jax.random.normal(KEY, (32, 16))
    _, mask = project(w, Row(0.5))
    norms = np.linalg.norm(np.asarray(w), axis=1)
    kept = np.nonzero(np.asarray(mask)[:, 0])[0]
    top = np.argsort(-norms)[:16]
    assert set(kept) == set(top)


def test_block_balanced_per_column():
    w = jax.random.normal(KEY, (128, 256))
    _, mask = project(w, Block(0.5, bm=32, bn=32))
    bm = np.asarray(mask).reshape(4, 32, 8, 32).any(axis=(1, 3))
    counts = bm.sum(axis=0)
    assert (counts == counts[0]).all(), "balanced projection must equalize columns"


def test_nm_structure():
    w = jax.random.normal(KEY, (64, 32))
    _, mask = project(w, NM(n_keep=2, m=4))
    groups = np.asarray(mask).reshape(16, 4, 32).sum(axis=1)
    assert (groups == 2).all()


def test_pattern_kernel_shapes_and_connectivity():
    w = jax.random.normal(KEY, (8, 4, 3, 3))
    st_ = PatternKernel(connectivity=0.5)
    _, mask = project(w, st_)
    m = np.asarray(mask)
    per_kernel = m.sum(axis=(2, 3))
    # live kernels have exactly 4 weights (the pattern), dead ones 0
    assert set(np.unique(per_kernel)).issubset({0.0, 4.0})
    assert (per_kernel > 0).mean() == pytest.approx(0.5, abs=0.05)


@given(
    sparsity=st.floats(0.1, 0.9),
    k=st.integers(2, 8),
)
@settings(max_examples=20, deadline=None)
def test_topk_mask_property(sparsity, k):
    """topk_mask keeps exactly k entries per axis slice, ties included."""
    scores = jax.random.uniform(jax.random.PRNGKey(k), (16, 32))
    mask = topk_mask(scores, k, axis=1)
    counts = np.asarray(mask).sum(axis=1)
    assert (counts == k).all()


@given(st.sampled_from([(0.3, 16), (0.5, 32), (0.7, 8)]))
@settings(max_examples=10, deadline=None)
def test_projection_distance_optimality(args):
    """Euclidean projection: no other mask with the same structure is closer."""
    sparsity, bn = args
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    st_ = Block(sparsity, bm=16, bn=bn, balanced=False)
    wp, mask = project(w, st_)
    d_opt = float(jnp.sum((w - wp) ** 2))
    # random same-cardinality block masks are never better
    kb, nb = 64 // 16, 64 // bn
    n_keep = int(np.asarray(mask).reshape(kb, 16, nb, bn).any(axis=(1, 3)).sum())
    rng = np.random.default_rng(0)
    for _ in range(10):
        bm_rand = np.zeros(kb * nb, bool)
        bm_rand[rng.choice(kb * nb, n_keep, replace=False)] = True
        m = np.repeat(np.repeat(bm_rand.reshape(kb, nb), 16, 0), bn, 1)
        d = float(np.sum((np.asarray(w) * (1 - m)) ** 2))
        assert d >= d_opt - 1e-4


# --------------------------------------------------------------------------- #
# ADMM                                                                         #
# --------------------------------------------------------------------------- #


def test_admm_converges_to_structure():
    """On a recoverable block-sparse regression, ADMM drives the primal
    residual down and hard-pruning is near-loss-neutral."""
    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (32, 32))}
    plan = PrunePlan.from_rules([("*['w']*", Block(0.5, bm=8, bn=8))], min_size=16)
    cfg = AdmmConfig(rho=0.3, rho_ramp=1.15, rho_max=3.0, update_every=1)
    state = admm_init(params, plan, cfg)
    assert list(state.structures) == ["['w']"]

    x = jax.random.normal(jax.random.PRNGKey(1), (256, 32))
    wtrue, _ = project(jax.random.normal(jax.random.PRNGKey(2), (32, 32)), Block(0.5, bm=8, bn=8))
    y = x @ wtrue

    def loss_fn(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    def total(p, s):
        return loss_fn(p) + admm_penalty(p, s)

    p = params
    step = jax.jit(lambda p, s: jax.tree.map(lambda a, g: a - 1e-2 * g, p, jax.grad(total)(p, s)))
    res0 = float(convergence_metrics(p, state)["primal_residual"])
    for it in range(400):
        p = step(p, state)
        if it % 10 == 9:
            state = admm_update(p, state, cfg)
    res1 = float(convergence_metrics(p, state)["primal_residual"])
    assert res1 < 0.5 * res0, (res0, res1)

    pruned, masks = hard_prune(p, state)
    rep = tree_sparsity_report(pruned, masks)
    assert rep["pruned_global"] == pytest.approx(0.5, abs=0.01)
    # hard prune near-loss-neutral after convergence
    assert float(loss_fn(pruned)) < float(loss_fn(p)) * 1.5 + 1e-3


def test_prune_plan_glob_and_min_size():
    params = {
        "layers": [{"ffn": {"w_gate": {"w": jnp.zeros((64, 128))}}}],
        "norm": {"scale": jnp.zeros((64,))},
    }
    plan = PrunePlan.from_rules([("*ffn*w_gate*['w']", Column(0.5))], min_size=128)
    assigned = plan.assign(params)
    assert len(assigned) == 1
    assert "w_gate" in next(iter(assigned))


def test_admm_state_is_pjit_compatible_pytree():
    params = {"w": jnp.zeros((16, 16))}
    plan = PrunePlan.from_rules([("*", Block(0.5, bm=8, bn=8))], min_size=16)
    state = admm_init(params, plan, AdmmConfig())
    leaves, treedef = jax.tree.flatten(state)
    state2 = jax.tree.unflatten(treedef, leaves)
    assert state2.structures == state.structures


def test_masked_training_keeps_sparsity():
    """Gradients through apply_masks never resurrect pruned weights."""
    w = jax.random.normal(KEY, (16, 16))
    _, mask = project(w, Block(0.5, bm=8, bn=8))
    params = {"w": w * mask}
    masks = {"w": mask}

    def loss(p):
        eff = apply_masks(p, masks)
        return jnp.sum(eff["w"] ** 2)

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["w"] * (1 - mask)).max()) == 0.0
