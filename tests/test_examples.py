"""Examples are runnable (subprocess smoke, tiny settings)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    out = subprocess.run([sys.executable] + args, capture_output=True, text=True,
                         timeout=timeout, env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


@pytest.mark.slow
def test_quickstart():
    out = _run(["examples/quickstart.py"])
    assert "OK" in out and "BSR kernel vs dense max err" in out


@pytest.mark.slow
def test_train_lm_tiny_with_prune():
    out = _run(["examples/train_lm_100m.py", "--tiny", "--steps", "25", "--prune"])
    assert "hard prune" in out and "trained 25 steps" in out


@pytest.mark.slow
def test_serve_pruned_lm():
    out = _run(["examples/serve_pruned_lm.py"])
    assert "OK" in out and "continuous batching" in out
